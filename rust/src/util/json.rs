//! Minimal JSON value model: writer + parser.
//!
//! No `serde` in the offline vendor set, so reports (`report/`) and the
//! artifact manifest (`runtime/artifacts`) use this hand-rolled JSON. The
//! parser accepts the JSON subset our own tools emit plus what
//! `python/compile/aot.py` writes for `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Insert/replace a key (no-op unless `self` is an object).
    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        }
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(JsonError::new(p.pos, "trailing characters"));
        }
        Ok(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Serialize documents as JSON Lines: one compact document per line,
/// trailing newline included when non-empty. This is the single
/// serialization/escaping path shared by `cxlmem exp all --json`
/// (wrapped in a `Json::Arr` instead) and the scenario JSONL emitters —
/// every byte goes through [`Json`]'s `Display` impl above.
pub fn to_jsonl<I: IntoIterator<Item = Json>>(docs: I) -> String {
    let mut out = String::new();
    for d in docs {
        out.push_str(&d.to_string());
        out.push('\n');
    }
    out
}

/// Parse a JSON Lines document (blank lines ignored).
pub fn parse_jsonl(text: &str) -> Result<Vec<Json>, JsonError> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(Json::parse)
        .collect()
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl JsonError {
    fn new(pos: usize, msg: &str) -> Self {
        Self {
            pos,
            msg: msg.to_string(),
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(JsonError::new(
                self.pos,
                &format!("expected '{}'", b as char),
            ))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(JsonError::new(self.pos, &format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::new(self.pos, "unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(JsonError::new(self.pos, "unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| JsonError::new(self.pos, "bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| JsonError::new(self.pos, "bad \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(JsonError::new(self.pos, "bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = &self.bytes[start..self.pos];
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| JsonError::new(start, "bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::new(start, "bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(JsonError::new(self.pos, "expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(JsonError::new(self.pos, "expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", "fig3".into()),
            ("threads", Json::arr((1..=4u64).map(Json::from))),
            ("peak_gbs", 38.4.into()),
            ("ok", true.into()),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "artifacts": [
                {"name": "adam", "file": "adam.hlo.txt",
                 "inputs": [[1024], [1024]], "dtype": "f32"}
            ],
            "version": 1
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("version").unwrap().as_u64(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("adam"));
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nbA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nbA"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_string_roundtrip() {
        let j = Json::Str("héllo → 世界".to_string());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn jsonl_roundtrip() {
        let docs = vec![
            Json::obj(vec![("a", 1u64.into())]),
            Json::obj(vec![("b", "x\ny".into())]),
        ];
        let text = to_jsonl(docs.clone());
        assert_eq!(text.lines().count(), 2, "escaped newline must not split lines");
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back, docs);
        assert!(parse_jsonl("").unwrap().is_empty());
    }

    #[test]
    fn accessors_and_set() {
        let mut j = Json::obj(vec![("n", 3u64.into()), ("f", true.into())]);
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("f").unwrap().as_bool(), Some(true));
        assert!(j.as_obj().is_some());
        j.set("n", 5u64.into());
        assert_eq!(j.get("n").unwrap().as_u64(), Some(5));
    }
}
