//! Deterministic pseudo-random number generation.
//!
//! The environment provides no `rand` crate, so this module implements the
//! generators the simulator needs from scratch: SplitMix64 (for seeding) and
//! xoshiro256** (the workhorse). Both are well-studied, public-domain
//! algorithms; determinism matters more than cryptographic strength here —
//! every experiment in the paper harness is reproducible from a seed.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid: state is
    /// expanded through SplitMix64, which never yields the all-zero state.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection method
    /// to avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s`, via rejection
    /// sampling (Devroye). Used for hot-page popularity distributions.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        if s <= 0.0 {
            return self.below(n);
        }
        // Rejection-inversion (Hörmann). Good for any n, s != 1 handled
        // via the generalized harmonic H function.
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-12 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let h_inv = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-12 {
                x.exp() - 1.0
            } else {
                ((1.0 - s) * x + 1.0).powf(1.0 / (1.0 - s)) - 1.0
            }
        };
        let hx0 = h(0.5) - 1.0;
        let hn = h(n as f64 - 0.5);
        loop {
            let u = hx0 + self.f64() * (hn - hx0);
            let x = h_inv(u);
            let k = (x + 0.5).floor().max(0.0).min(n as f64 - 1.0);
            let rank = k as u64;
            // acceptance test
            if u >= h(k - 0.5) - (1.0 + k).powf(-s) {
                return rank;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::seeded(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::seeded(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::seeded(13);
        let n = 1000u64;
        let mut counts = vec![0usize; n as usize];
        for _ in 0..50_000 {
            let v = r.zipf(n, 1.1);
            assert!(v < n);
            counts[v as usize] += 1;
        }
        // rank 0 must dominate rank 100 heavily under zipf(1.1)
        assert!(counts[0] > 10 * counts[100].max(1));
    }

    #[test]
    fn zipf_zero_exponent_uniform() {
        let mut r = Rng::seeded(17);
        let mut counts = [0usize; 4];
        for _ in 0..8000 {
            counts[r.zipf(4, 0.0) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 2000.0).abs() < 300.0);
        }
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::seeded(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::seeded(23);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
