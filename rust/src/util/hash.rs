//! Content hashing for cache keys (no `xxhash`/`siphash` crates in the
//! offline vendor set): FNV-1a 64-bit over bytes.
//!
//! Used by the scenario-result cache, which indexes entries by the hash
//! of a spec's canonical serialization
//! ([`crate::scenario::ScenarioSpec::canonical_string`]). FNV-1a 64 is
//! fast but not collision-free, so the cache also stores the canonical
//! string itself and verifies it on every hit — a collision costs a
//! re-evaluation, never a wrong result.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64-bit hasher.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    state: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Self { state: FNV_OFFSET }
    }

    /// Absorb bytes (order-sensitive, streaming-safe: hashing in chunks
    /// equals hashing the concatenation).
    pub fn write(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.state = h;
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// One-shot hash of a byte slice.
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// One-shot hash of a string's UTF-8 bytes.
pub fn hash_str(s: &str) -> u64 {
    hash_bytes(s.as_bytes())
}

/// Fixed-width lowercase hex rendering (16 chars) — the on-disk key form.
pub fn hex16(h: u64) -> String {
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_is_offset_basis() {
        assert_eq!(hash_bytes(b""), FNV_OFFSET);
    }

    #[test]
    fn deterministic_and_order_sensitive() {
        assert_eq!(hash_str("cxlmem"), hash_str("cxlmem"));
        assert_ne!(hash_str("ab"), hash_str("ba"));
        assert_ne!(hash_str("a"), hash_str("a\0"));
    }

    #[test]
    fn streaming_matches_one_shot() {
        let mut h = Fnv64::new();
        h.write(b"hello ");
        h.write(b"world");
        assert_eq!(h.finish(), hash_bytes(b"hello world"));
    }

    #[test]
    fn hex16_is_fixed_width() {
        assert_eq!(hex16(0), "0000000000000000");
        assert_eq!(hex16(0xabc), "0000000000000abc");
        assert_eq!(hex16(u64::MAX), "ffffffffffffffff");
        assert_eq!(hex16(hash_str("x")).len(), 16);
    }
}
