//! Hand-rolled utility substrates (no external crates available offline):
//! PRNG, statistics, table rendering, JSON, CLI parsing, content hashing,
//! advisory file locking, fault injection, cooperative cancellation, and
//! a bench timer.

pub mod cancel;
pub mod cli;
pub mod fault;
pub mod hash;
pub mod json;
pub mod lock;
pub mod metrics;
pub mod par;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;
