//! Process-global metrics registry — the always-on observability layer
//! the future `scenario serve` daemon's `stats` verb is built on.
//!
//! Idiom (the dataplane `stats`/`metricks` shape): instrumentation
//! sites register a metric **once** by name and keep the returned
//! `&'static` handle, so the hot path is a single relaxed atomic op —
//! no locks, no map lookups, no formatting. The registry lock is only
//! taken at registration (first touch per site) and at
//! [`Registry::snapshot`] time.
//!
//! Three metric kinds:
//!
//! - [`Counter`] — monotone `u64` (requests, hits, misses).
//! - [`Gauge`] — signed level with a high-water mark (jobs in flight,
//!   entries held). [`GaugeGuard`] gives RAII inc/dec for queue depths.
//! - [`Histogram`] — fixed-bucket log-scale duration histogram:
//!   [`BUCKETS`] buckets covering all of `u64` ns with ≤ 12.5% relative
//!   width (8 sub-buckets per power of two). Quantile extraction
//!   ([`Histogram::quantile`]) linearly interpolates ranks over the
//!   multiset of bucket representatives — the same rank arithmetic as
//!   [`crate::util::stats::percentile`], so on data that lands on
//!   bucket representatives the two agree exactly (pinned by test).
//!   `util::timer` builds its bench p50/p90 from the *same* bucket
//!   code, so bench and runtime telemetry share bucket edges.
//!
//! [`Registry::snapshot`] renders everything to [`crate::util::json`]
//! as schema [`METRICS_SCHEMA`] (`cxlmem-metrics-v1`): counters,
//! gauges (value + high-water mark), histograms (count/sum/max,
//! p10/p50/p90, and the sparse bucket list so sidecars from N shards
//! can be merged exactly), and per-family rate windows — each snapshot
//! records `(t, value)` per counter family (the name prefix before the
//! first `.`), and consecutive snapshots yield events/second over a
//! short window, the serve-daemon "requests per second" view.
//!
//! The global registry ([`global`]) is enabled unless the
//! `CXLMEM_METRICS` environment variable is `0`/`off`/`false`. A
//! disabled registry hands out shared null sinks and registers
//! **nothing** — snapshots stay empty and hot paths stay one atomic op.
//!
//! Instrumentation must stay off the parity-pinned *reference* paths
//! (`perf::with_reference`): the reference implementations are the
//! seed-semantics baselines the golden suite compares against, and they
//! stay byte-for-byte untouched. Counters never change results either
//! way — the parity test in `rust/tests/metrics.rs` pins that.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::json::Json;

/// Snapshot schema identifier.
pub const METRICS_SCHEMA: &str = "cxlmem-metrics-v1";

/// Number of histogram buckets: values 0..16 exact, then 8 sub-buckets
/// per power of two up to `u64::MAX` (see [`bucket_index`]).
pub const BUCKETS: usize = 496;

/// Observations kept per rate window (one per snapshot call).
const RATE_WINDOW: usize = 8;

// ---------------------------------------------------------------------------
// Bucket math — shared by Histogram and util::timer.
// ---------------------------------------------------------------------------

/// Bucket index of `v`: identity for `v < 16`, then log-scale with 8
/// sub-buckets per octave (≤ 12.5% relative bucket width). Monotone and
/// contiguous over all of `u64`.
pub fn bucket_index(v: u64) -> usize {
    if v < 16 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros() as usize;
        let shift = msb - 3;
        shift * 8 + (v >> shift) as usize
    }
}

/// Representative (lower edge) of bucket `i` — the value every member
/// of the bucket reports as. `bucket_value(bucket_index(v)) <= v` for
/// all `v`, with equality exactly on representatives.
pub fn bucket_value(i: usize) -> u64 {
    if i < 16 {
        i as u64
    } else {
        let shift = (i >> 3) - 1;
        (((i & 7) | 8) as u64) << shift
    }
}

/// Quantile (`p` in [0, 100]) over a sparse `bucket index -> count`
/// multiset of bucket representatives, by linear interpolation on ranks
/// — the exact arithmetic of [`crate::util::stats::percentile`] applied
/// to the expanded multiset, without expanding it.
pub fn quantile_of_sparse(buckets: &BTreeMap<usize, u64>, p: f64) -> f64 {
    let n: u64 = buckets.values().sum();
    if n == 0 {
        return 0.0;
    }
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as u64;
    let hi = rank.ceil() as u64;
    let mut seen = 0u64;
    let (mut v_lo, mut v_hi) = (None, None);
    for (&b, &c) in buckets {
        if c == 0 {
            continue;
        }
        seen += c;
        if v_lo.is_none() && seen > lo {
            v_lo = Some(bucket_value(b) as f64);
        }
        if seen > hi {
            v_hi = Some(bucket_value(b) as f64);
            break;
        }
    }
    let v_lo = v_lo.unwrap_or(0.0);
    let v_hi = v_hi.unwrap_or(v_lo);
    v_lo + (rank - lo as f64) * (v_hi - v_lo)
}

// ---------------------------------------------------------------------------
// Metric kinds.
// ---------------------------------------------------------------------------

/// Monotone counter (one relaxed atomic add on the hot path).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Signed level gauge with a high-water mark (queue depth, bytes held).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
    hwm: AtomicI64,
}

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge {
            value: AtomicI64::new(0),
            hwm: AtomicI64::new(0),
        }
    }

    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
        self.hwm.fetch_max(v, Ordering::Relaxed);
    }

    /// Add `d` (may be negative) and return the new level; raises the
    /// high-water mark when the new level exceeds it.
    pub fn add(&self, d: i64) -> i64 {
        let v = self.value.fetch_add(d, Ordering::Relaxed) + d;
        self.hwm.fetch_max(v, Ordering::Relaxed);
        v
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }

    /// Highest level ever set/reached since the last reset.
    pub fn hwm(&self) -> i64 {
        self.hwm.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.value.store(0, Ordering::Relaxed);
        self.hwm.store(0, Ordering::Relaxed);
    }
}

/// RAII in-flight marker: +1 on construction, −1 on drop (panic-safe),
/// so "jobs in flight" gauges can never leak a decrement.
pub struct GaugeGuard(&'static Gauge);

impl GaugeGuard {
    pub fn enter(g: &'static Gauge) -> GaugeGuard {
        g.add(1);
        GaugeGuard(g)
    }
}

impl Drop for GaugeGuard {
    fn drop(&mut self) {
        self.0.add(-1);
    }
}

/// Fixed-bucket log-scale histogram (durations in ns, but any `u64`
/// works). Recording is one relaxed add per bucket plus the count/sum/
/// max updates; quantiles are extracted at snapshot time.
pub struct Histogram {
    counts: Vec<AtomicU64>,
    n: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        let mut counts = Vec::with_capacity(BUCKETS);
        counts.resize_with(BUCKETS, AtomicU64::default);
        Histogram {
            counts,
            n: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.n.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Time `f` and record the elapsed nanoseconds.
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.record(t0.elapsed().as_nanos().min(u64::MAX as u128) as u64);
        r
    }

    pub fn count(&self) -> u64 {
        self.n.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Sparse `bucket index -> count` view (snapshot-consistent within
    /// itself: quantiles derived from it use its own total).
    pub fn sparse(&self) -> BTreeMap<usize, u64> {
        let mut out = BTreeMap::new();
        for (i, c) in self.counts.iter().enumerate() {
            let c = c.load(Ordering::Relaxed);
            if c > 0 {
                out.insert(i, c);
            }
        }
        out
    }

    /// Quantile over recorded values' bucket representatives; matches
    /// [`crate::util::stats::percentile`] on representative-valued data.
    pub fn quantile(&self, p: f64) -> f64 {
        quantile_of_sparse(&self.sparse(), p)
    }

    pub fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.n.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("max", &self.max())
            .finish()
    }
}

/// A detached (never registered) counter — for per-instance stats that
/// should not appear in snapshots, e.g. private `TraceStore`s in tests.
pub fn detached_counter() -> &'static Counter {
    Box::leak(Box::new(Counter::new()))
}

/// A detached (never registered) gauge.
pub fn detached_gauge() -> &'static Gauge {
    Box::leak(Box::new(Gauge::new()))
}

// ---------------------------------------------------------------------------
// Registry.
// ---------------------------------------------------------------------------

enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

#[derive(Default)]
struct Windows {
    /// Rate window per counter *family* (name prefix before the first
    /// '.'): up to [`RATE_WINDOW`] `(now_ns, summed value)` observations,
    /// one appended per snapshot.
    obs: BTreeMap<String, VecDeque<(u64, u64)>>,
}

/// Named metric registry; see the module docs. All handles it returns
/// are `&'static` — registered metrics live for the process.
pub struct Registry {
    enabled: bool,
    metrics: Mutex<BTreeMap<String, Metric>>,
    windows: Mutex<Windows>,
    start: Instant,
}

fn family_of(name: &str) -> &str {
    name.split('.').next().unwrap_or(name)
}

impl Registry {
    pub fn new(enabled: bool) -> Registry {
        Registry {
            enabled,
            metrics: Mutex::new(BTreeMap::new()),
            windows: Mutex::new(Windows::default()),
            start: Instant::now(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Metric>> {
        // Registration/snapshot only ever do map bookkeeping; recover
        // from a panicked holder instead of poisoning the process.
        self.metrics.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// The counter registered under `name` (first call registers it).
    /// Panics if `name` is already registered as a different kind —
    /// that is a programming error, not a runtime condition.
    pub fn counter(&self, name: &str) -> &'static Counter {
        if !self.enabled {
            static NULL: Counter = Counter::new();
            return &NULL;
        }
        let mut m = self.lock();
        match m.get(name) {
            Some(Metric::Counter(c)) => c,
            Some(_) => panic!("metric '{name}' already registered as a different kind"),
            None => {
                let c: &'static Counter = Box::leak(Box::new(Counter::new()));
                m.insert(name.to_string(), Metric::Counter(c));
                c
            }
        }
    }

    /// The gauge registered under `name` (first call registers it).
    pub fn gauge(&self, name: &str) -> &'static Gauge {
        if !self.enabled {
            static NULL: Gauge = Gauge::new();
            return &NULL;
        }
        let mut m = self.lock();
        match m.get(name) {
            Some(Metric::Gauge(g)) => g,
            Some(_) => panic!("metric '{name}' already registered as a different kind"),
            None => {
                let g: &'static Gauge = Box::leak(Box::new(Gauge::new()));
                m.insert(name.to_string(), Metric::Gauge(g));
                g
            }
        }
    }

    /// The histogram registered under `name` (first call registers it).
    pub fn histogram(&self, name: &str) -> &'static Histogram {
        if !self.enabled {
            static NULL: OnceLock<&'static Histogram> = OnceLock::new();
            return NULL.get_or_init(|| Box::leak(Box::new(Histogram::new())));
        }
        let mut m = self.lock();
        match m.get(name) {
            Some(Metric::Histogram(h)) => h,
            Some(_) => panic!("metric '{name}' already registered as a different kind"),
            None => {
                let h: &'static Histogram = Box::leak(Box::new(Histogram::new()));
                m.insert(name.to_string(), Metric::Histogram(h));
                h
            }
        }
    }

    /// Registered metric names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.lock().keys().cloned().collect()
    }

    /// Zero every registered metric and drop the rate windows (between
    /// runs in one process; sidecar emission does *not* reset).
    pub fn reset(&self) {
        for metric in self.lock().values() {
            match metric {
                Metric::Counter(c) => c.reset(),
                Metric::Gauge(g) => g.reset(),
                Metric::Histogram(h) => h.reset(),
            }
        }
        self.windows.lock().unwrap_or_else(|p| p.into_inner()).obs.clear();
    }

    /// Render the registry as a `cxlmem-metrics-v1` document, stamping
    /// this process's monotonic clock into the rate windows.
    pub fn snapshot(&self) -> Json {
        self.snapshot_at(self.start.elapsed().as_nanos().min(u64::MAX as u128) as u64)
    }

    /// [`Registry::snapshot`] with an explicit `now` (ns since some
    /// fixed origin) — deterministic rate windows for tests.
    pub fn snapshot_at(&self, now_ns: u64) -> Json {
        let m = self.lock();
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut hists = BTreeMap::new();
        let mut family_totals: BTreeMap<String, u64> = BTreeMap::new();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => {
                    let v = c.get();
                    *family_totals.entry(family_of(name).to_string()).or_insert(0) += v;
                    counters.insert(name.clone(), Json::from(v));
                }
                Metric::Gauge(g) => {
                    gauges.insert(
                        name.clone(),
                        Json::obj(vec![
                            ("value", (g.get() as f64).into()),
                            ("hwm", (g.hwm() as f64).into()),
                        ]),
                    );
                }
                Metric::Histogram(h) => {
                    let sparse = h.sparse();
                    let buckets = Json::arr(
                        sparse
                            .iter()
                            .map(|(&b, &c)| Json::arr([Json::from(b), Json::from(c)])),
                    );
                    hists.insert(
                        name.clone(),
                        Json::obj(vec![
                            ("count", h.count().into()),
                            ("sum", h.sum().into()),
                            ("max", h.max().into()),
                            ("p10", quantile_of_sparse(&sparse, 10.0).into()),
                            ("p50", quantile_of_sparse(&sparse, 50.0).into()),
                            ("p90", quantile_of_sparse(&sparse, 90.0).into()),
                            ("buckets", buckets),
                        ]),
                    );
                }
            }
        }
        drop(m);

        // Per-family rate windows: events/second between the oldest
        // retained observation and now.
        let mut rates = BTreeMap::new();
        let mut w = self.windows.lock().unwrap_or_else(|p| p.into_inner());
        for (family, total) in &family_totals {
            let win = w.obs.entry(family.clone()).or_default();
            if let Some(&(t0, v0)) = win.front() {
                if now_ns > t0 {
                    let per_s = (total.saturating_sub(v0)) as f64 / ((now_ns - t0) as f64 / 1e9);
                    rates.insert(format!("{family}.per_s"), Json::from(per_s));
                }
            }
            win.push_back((now_ns, *total));
            while win.len() > RATE_WINDOW {
                win.pop_front();
            }
        }

        Json::obj(vec![
            ("schema", METRICS_SCHEMA.into()),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
            ("rates", Json::Obj(rates)),
        ])
    }
}

/// The process-global registry every instrumentation site uses. Enabled
/// unless `CXLMEM_METRICS` is `0`/`off`/`false`.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let off = matches!(
            std::env::var("CXLMEM_METRICS").as_deref(),
            Ok("0") | Ok("off") | Ok("false")
        );
        Registry::new(!off)
    })
}

/// [`Registry::counter`] on the global registry.
pub fn counter(name: &str) -> &'static Counter {
    global().counter(name)
}

/// [`Registry::gauge`] on the global registry.
pub fn gauge(name: &str) -> &'static Gauge {
    global().gauge(name)
}

/// [`Registry::histogram`] on the global registry.
pub fn histogram(name: &str) -> &'static Histogram {
    global().histogram(name)
}

/// [`Registry::snapshot`] of the global registry.
pub fn snapshot() -> Json {
    global().snapshot()
}

// ---------------------------------------------------------------------------
// Schema validation.
// ---------------------------------------------------------------------------

fn finite_nonneg(doc: &Json, what: &str) -> Result<f64> {
    let v = doc
        .as_f64()
        .ok_or_else(|| anyhow!("{what}: not a number"))?;
    if !v.is_finite() || v < 0.0 {
        bail!("{what}: must be finite and >= 0 (got {v})");
    }
    Ok(v)
}

/// Validate a parsed metrics sidecar against schema `cxlmem-metrics-v1`
/// — the gate behind `cxlmem stats --validate FILE` and
/// `make metrics-smoke`.
pub fn validate_metrics_doc(doc: &Json) -> Result<()> {
    match doc.get("schema").and_then(Json::as_str) {
        Some(s) if s == METRICS_SCHEMA => {}
        Some(s) => bail!("schema is '{s}', want '{METRICS_SCHEMA}'"),
        None => bail!("missing string field 'schema'"),
    }
    for section in ["counters", "gauges", "histograms", "rates"] {
        if doc.get(section).and_then(Json::as_obj).is_none() {
            bail!("missing object field '{section}'");
        }
    }
    for (name, v) in doc.get("counters").unwrap().as_obj().unwrap() {
        finite_nonneg(v, &format!("counters['{name}']"))?;
    }
    for (name, g) in doc.get("gauges").unwrap().as_obj().unwrap() {
        for field in ["value", "hwm"] {
            let f = g
                .get(field)
                .ok_or_else(|| anyhow!("gauges['{name}']: missing numeric '{field}'"))?;
            if f.as_f64().map_or(true, |x| !x.is_finite()) {
                bail!("gauges['{name}'].{field}: must be a finite number");
            }
        }
    }
    for (name, h) in doc.get("histograms").unwrap().as_obj().unwrap() {
        for field in ["count", "sum", "max", "p10", "p50", "p90"] {
            let f = h
                .get(field)
                .ok_or_else(|| anyhow!("histograms['{name}']: missing numeric '{field}'"))?;
            finite_nonneg(f, &format!("histograms['{name}'].{field}"))?;
        }
        let buckets = h
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("histograms['{name}']: missing array 'buckets'"))?;
        let mut total = 0.0;
        for (i, pair) in buckets.iter().enumerate() {
            let pair = pair
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow!("histograms['{name}'].buckets[{i}]: want [index, count]"))?;
            let idx = finite_nonneg(&pair[0], &format!("histograms['{name}'].buckets[{i}][0]"))?;
            if idx as usize >= BUCKETS {
                bail!("histograms['{name}'].buckets[{i}]: index {idx} >= {BUCKETS}");
            }
            total += finite_nonneg(&pair[1], &format!("histograms['{name}'].buckets[{i}][1]"))?;
        }
        let count = h.get("count").unwrap().as_f64().unwrap();
        if (total - count).abs() > 0.5 {
            bail!("histograms['{name}']: bucket counts sum to {total}, 'count' is {count}");
        }
    }
    for (name, v) in doc.get("rates").unwrap().as_obj().unwrap() {
        finite_nonneg(v, &format!("rates['{name}']"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::par::par_map;
    use crate::util::stats;

    #[test]
    fn bucket_index_is_monotone_and_contiguous() {
        let mut prev = bucket_index(0);
        assert_eq!(prev, 0);
        for v in 1..4096u64 {
            let b = bucket_index(v);
            assert!(b == prev || b == prev + 1, "gap at {v}: {prev} -> {b}");
            prev = b;
        }
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        // Representatives round-trip and lower-bound their buckets.
        for i in 0..BUCKETS {
            let v = bucket_value(i);
            assert_eq!(bucket_index(v), i, "representative of bucket {i}");
        }
        for v in [0u64, 1, 15, 16, 17, 1000, 1 << 20, u64::MAX] {
            assert!(bucket_value(bucket_index(v)) <= v);
        }
    }

    #[test]
    fn histogram_quantiles_match_report_percentiles_on_known_data() {
        // scenario::report quantiles go through util::stats::percentile
        // (linear rank interpolation). On data made of exact bucket
        // representatives the histogram must reproduce them bit-for-bit
        // — same rank arithmetic, same values.
        let h = Histogram::new();
        let mut raw: Vec<f64> = Vec::new();
        for i in [0usize, 1, 2, 3, 7, 12, 15, 16, 24, 100, 200, 300, 400] {
            let v = bucket_value(i);
            // Uneven repeats so ranks fall inside and between buckets.
            for _ in 0..(i % 5 + 1) {
                h.record(v);
                raw.push(v as f64);
            }
        }
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let want = stats::percentile(&raw, p);
            let got = h.quantile(p);
            assert_eq!(got, want, "p{p}");
        }
        assert_eq!(h.max() as f64, stats::percentile(&raw, 100.0));
        assert_eq!(h.count(), raw.len() as u64);
    }

    #[test]
    fn concurrent_counter_increments_are_not_torn() {
        let reg = Registry::new(true);
        let c = reg.counter("t.concurrent.incs");
        let g = reg.gauge("t.concurrent.level");
        let h = reg.histogram("t.concurrent.ns");
        let lanes: Vec<u64> = (0..8).collect();
        par_map(&lanes, 4, |_| {
            for i in 0..10_000u64 {
                c.inc();
                if i % 64 == 0 {
                    let _guard = GaugeGuard::enter(g);
                    h.record(i);
                }
            }
        });
        assert_eq!(c.get(), 8 * 10_000);
        assert_eq!(h.count(), 8 * 157); // ceil(10000/64) = 157 recordings per lane
        assert_eq!(g.get(), 0, "every guard decremented");
        assert!(g.hwm() >= 1);
        let snap = reg.snapshot_at(1_000);
        let counted = snap
            .get("counters")
            .unwrap()
            .get("t.concurrent.incs")
            .unwrap()
            .as_u64()
            .unwrap();
        assert_eq!(counted, 80_000, "snapshot must agree with the handles");
    }

    #[test]
    fn disabled_registry_adds_no_entries() {
        let reg = Registry::new(false);
        reg.counter("x.hits").add(5);
        reg.gauge("x.depth").set(3);
        reg.histogram("x.ns").record(100);
        assert!(reg.names().is_empty());
        let snap = reg.snapshot_at(0);
        for section in ["counters", "gauges", "histograms", "rates"] {
            assert!(
                snap.get(section).unwrap().as_obj().unwrap().is_empty(),
                "{section} must stay empty when disabled"
            );
        }
        // The null sinks still absorb writes without panicking, and the
        // empty snapshot still validates.
        validate_metrics_doc(&snap).unwrap();
    }

    #[test]
    fn snapshot_validates_and_windows_report_rates() {
        let reg = Registry::new(true);
        let c = reg.counter("req.total");
        c.add(100);
        let s1 = reg.snapshot_at(1_000_000_000); // t = 1 s
        validate_metrics_doc(&s1).unwrap();
        assert!(s1.get("rates").unwrap().as_obj().unwrap().is_empty());
        c.add(300);
        let s2 = reg.snapshot_at(3_000_000_000); // t = 3 s: +300 in 2 s
        validate_metrics_doc(&s2).unwrap();
        let rate = s2
            .get("rates")
            .unwrap()
            .get("req.per_s")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((rate - 150.0).abs() < 1e-9, "got {rate}");
    }

    #[test]
    fn histogram_snapshot_buckets_merge_exactly() {
        // Two "shards" record different halves; merging their sparse
        // bucket lists must give the union histogram's quantiles.
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for v in 0..8u64 {
            a.record(v);
            all.record(v);
        }
        for v in 8..16u64 {
            b.record(v);
            all.record(v);
        }
        let mut merged = a.sparse();
        for (k, v) in b.sparse() {
            *merged.entry(k).or_insert(0) += v;
        }
        for p in [10.0, 50.0, 90.0] {
            assert_eq!(quantile_of_sparse(&merged, p), all.quantile(p), "p{p}");
        }
    }

    #[test]
    fn validate_rejects_malformed_docs() {
        assert!(validate_metrics_doc(&Json::parse("{}").unwrap()).is_err());
        let wrong = Json::obj(vec![("schema", "cxlmem-bench-v1".into())]);
        assert!(validate_metrics_doc(&wrong).is_err());
        // A histogram whose bucket counts disagree with 'count'.
        let bad = Json::parse(
            r#"{"schema": "cxlmem-metrics-v1", "counters": {}, "gauges": {},
                "histograms": {"h": {"count": 5, "sum": 1, "max": 1,
                  "p10": 0, "p50": 0, "p90": 0, "buckets": [[1, 2]]}},
                "rates": {}}"#,
        )
        .unwrap();
        let err = validate_metrics_doc(&bad).unwrap_err().to_string();
        assert!(err.contains("bucket counts"), "{err}");
    }

    #[test]
    fn reset_zeroes_everything() {
        let reg = Registry::new(true);
        reg.counter("r.c").add(7);
        reg.gauge("r.g").set(9);
        reg.histogram("r.h").record(1234);
        reg.reset();
        assert_eq!(reg.counter("r.c").get(), 0);
        assert_eq!((reg.gauge("r.g").get(), reg.gauge("r.g").hwm()), (0, 0));
        assert_eq!(reg.histogram("r.h").count(), 0);
    }
}
