//! Advisory cross-process file locking (no `fs2`/`libc` crates in the
//! offline vendor set).
//!
//! [`FileLock::acquire`] blocks until it holds an exclusive advisory
//! lock on the given lock file, and releases it on drop. On Unix this is
//! `flock(2)` (declared directly against the C library std already
//! links), so the lock is shared correctly between processes *and*
//! between threads of one process — each acquire opens its own file
//! description. Crashed holders cost nothing: the kernel drops the lock
//! with the file descriptor. On non-Unix platforms a best-effort
//! create-new spinlock on `<path>.held` stands in (a crashed holder
//! leaves the marker behind; delete it by hand).
//!
//! Used by the scenario-result cache ([`crate::scenario::cache`]) so N
//! sharded processes pointed at one `--cache-dir` can append to the
//! shared store without tearing lines.

use std::fs::{File, OpenOptions};
use std::io;
use std::path::Path;

/// An exclusive advisory lock, held until drop.
#[derive(Debug)]
pub struct FileLock {
    _held: imp::Held,
}

impl FileLock {
    /// Block until the exclusive advisory lock on `path` is held. The
    /// lock file is created if missing and intentionally left in place
    /// afterwards — deleting it would race other acquirers.
    pub fn acquire(path: &Path) -> io::Result<FileLock> {
        Ok(FileLock {
            _held: imp::acquire(path)?,
        })
    }
}

/// Open (create if needed) the lock file itself.
fn open_lock_file(path: &Path) -> io::Result<File> {
    OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .open(path)
}

#[cfg(unix)]
mod imp {
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;
    use std::path::Path;

    const LOCK_EX: i32 = 2;

    extern "C" {
        fn flock(fd: i32, operation: i32) -> i32;
    }

    /// The flock is tied to this file description: closing the file on
    /// drop releases it (no explicit unlock needed, and the kernel also
    /// releases it if the process dies).
    #[derive(Debug)]
    pub struct Held {
        _file: File,
    }

    pub fn acquire(path: &Path) -> io::Result<Held> {
        let file = super::open_lock_file(path)?;
        loop {
            if unsafe { flock(file.as_raw_fd(), LOCK_EX) } == 0 {
                return Ok(Held { _file: file });
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

#[cfg(not(unix))]
mod imp {
    use std::io;
    use std::path::{Path, PathBuf};

    /// Best-effort fallback: exclusive creation of a `.held` marker next
    /// to the lock file, removed on drop. Unlike `flock(2)`, a crashed
    /// holder leaves the marker behind, so acquisition is *bounded*:
    /// after ~5 s of contention it errors out naming the marker, and
    /// callers degrade (the scenario cache proceeds unlocked with a
    /// warning) instead of hanging forever.
    #[derive(Debug)]
    pub struct Held {
        marker: PathBuf,
    }

    pub fn acquire(path: &Path) -> io::Result<Held> {
        // Keep the lock file itself existing for path parity with Unix.
        let _ = super::open_lock_file(path)?;
        let mut name = path.as_os_str().to_os_string();
        name.push(".held");
        let marker = PathBuf::from(name);
        for _ in 0..2500 {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&marker)
            {
                Ok(_) => return Ok(Held { marker }),
                Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                Err(e) => return Err(e),
            }
        }
        Err(io::Error::new(
            io::ErrorKind::TimedOut,
            format!(
                "lock marker {} held too long (stale from a crash? delete it by hand)",
                marker.display()
            ),
        ))
    }

    impl Drop for Held {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.marker);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cxlmem-lock-{tag}-{}", std::process::id()))
    }

    #[test]
    fn acquire_creates_and_reacquires() {
        let path = tmp("basic");
        let _ = std::fs::remove_file(&path);
        {
            let _l = FileLock::acquire(&path).unwrap();
            assert!(path.exists());
        }
        // Released on drop: a second acquire must not block.
        let _l2 = FileLock::acquire(&path).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    /// Mutual exclusion between concurrent acquirers (threads here; each
    /// acquire opens its own file description, so the same mechanism
    /// excludes separate processes): read-modify-write of a counter file
    /// under the lock must lose no update.
    #[test]
    fn read_modify_write_loses_no_update() {
        let lock_path = tmp("rmw");
        let data_path = tmp("rmw-data");
        let _ = std::fs::remove_file(&lock_path);
        std::fs::write(&data_path, "0").unwrap();

        const THREADS: usize = 4;
        const ITERS: usize = 25;
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..ITERS {
                        let _l = FileLock::acquire(&lock_path).unwrap();
                        let n: u64 = std::fs::read_to_string(&data_path)
                            .unwrap()
                            .trim()
                            .parse()
                            .unwrap();
                        std::fs::write(&data_path, format!("{}", n + 1)).unwrap();
                    }
                });
            }
        });
        let n: u64 = std::fs::read_to_string(&data_path)
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_eq!(n as usize, THREADS * ITERS, "lost updates under the lock");
        let _ = std::fs::remove_file(&lock_path);
        let _ = std::fs::remove_file(&data_path);
    }
}
