//! Advisory cross-process file locking (no `fs2`/`libc` crates in the
//! offline vendor set).
//!
//! [`FileLock::acquire`] blocks until it holds an exclusive advisory
//! lock on the given lock file, and releases it on drop. On Unix this is
//! `flock(2)` (declared directly against the C library std already
//! links), so the lock is shared correctly between processes *and*
//! between threads of one process — each acquire opens its own file
//! description. Crashed holders cost nothing: the kernel drops the lock
//! with the file descriptor.
//!
//! On non-Unix platforms the [`marker`] fallback stands in: exclusive
//! creation of a `<path>.held` marker file. Unlike `flock(2)`, a
//! crashed holder leaves the marker behind, so acquisition is
//! **bounded** and **self-healing**: waiters back off exponentially
//! (capped), break markers older than a staleness threshold (counted in
//! the `lock.stale_broken` metric — a broken marker means a holder
//! died), and return a clear [`io::ErrorKind::TimedOut`] error instead
//! of hanging a shard forever. The marker module is compiled on every
//! platform so its semantics are pinned by tests wherever the suite
//! runs; only non-Unix builds route `FileLock` through it.
//!
//! Used by the scenario-result cache ([`crate::scenario::cache`]) so N
//! sharded processes pointed at one `--cache-dir` can append to the
//! shared store without tearing lines. `FileLock::acquire` is also a
//! fault-injection point (`lock.acquire`, keyed by the lock path) so
//! the chaos harness can manufacture lock contention deterministically.

use std::fs::{File, OpenOptions};
use std::io;
use std::path::Path;

use super::fault;

/// An exclusive advisory lock, held until drop.
#[derive(Debug)]
pub struct FileLock {
    _held: imp::Held,
}

impl FileLock {
    /// Block until the exclusive advisory lock on `path` is held. The
    /// lock file is created if missing and intentionally left in place
    /// afterwards — deleting it would race other acquirers.
    pub fn acquire(path: &Path) -> io::Result<FileLock> {
        // Chaos hook: a `delay` rule here simulates a slow/contended
        // holder; an `io` rule simulates an unlockable store.
        fault::io_point("lock.acquire", &path.to_string_lossy())?;
        Ok(FileLock {
            _held: imp::acquire(path)?,
        })
    }

    /// Try to take the lock without waiting: `Ok(None)` means another
    /// holder has it right now. Used by background maintenance (the
    /// store compactor) that should skip rather than queue — whoever
    /// holds the lock is doing equivalent work.
    pub fn try_acquire(path: &Path) -> io::Result<Option<FileLock>> {
        fault::io_point("lock.acquire", &path.to_string_lossy())?;
        Ok(imp::try_acquire(path)?.map(|held| FileLock { _held: held }))
    }
}

/// Open (create if needed) the lock file itself.
fn open_lock_file(path: &Path) -> io::Result<File> {
    OpenOptions::new()
        .read(true)
        .write(true)
        .create(true)
        .open(path)
}

#[cfg(unix)]
mod imp {
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;
    use std::path::Path;

    const LOCK_EX: i32 = 2;
    const LOCK_NB: i32 = 4;

    extern "C" {
        fn flock(fd: i32, operation: i32) -> i32;
    }

    /// The flock is tied to this file description: closing the file on
    /// drop releases it (no explicit unlock needed, and the kernel also
    /// releases it if the process dies).
    #[derive(Debug)]
    pub struct Held {
        _file: File,
    }

    pub fn acquire(path: &Path) -> io::Result<Held> {
        let file = super::open_lock_file(path)?;
        loop {
            if unsafe { flock(file.as_raw_fd(), LOCK_EX) } == 0 {
                return Ok(Held { _file: file });
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }

    pub fn try_acquire(path: &Path) -> io::Result<Option<Held>> {
        let file = super::open_lock_file(path)?;
        loop {
            if unsafe { flock(file.as_raw_fd(), LOCK_EX | LOCK_NB) } == 0 {
                return Ok(Some(Held { _file: file }));
            }
            let err = io::Error::last_os_error();
            if err.kind() == io::ErrorKind::WouldBlock {
                return Ok(None);
            }
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

#[cfg(not(unix))]
mod imp {
    use std::io;
    use std::path::Path;

    pub type Held = super::marker::Held;

    pub fn acquire(path: &Path) -> io::Result<Held> {
        super::marker::acquire(path, &super::marker::MarkerOpts::default())
    }

    pub fn try_acquire(path: &Path) -> io::Result<Option<Held>> {
        match super::marker::try_acquire(path) {
            Ok(held) => Ok(Some(held)),
            Err(e) if e.kind() == io::ErrorKind::AlreadyExists => Ok(None),
            Err(e) => Err(e),
        }
    }
}

/// Create-new marker fallback lock (see the module docs). Compiled on
/// every platform so its bounded-wait and stale-break semantics stay
/// tested; non-Unix `FileLock` builds on it.
pub mod marker {
    use std::io;
    use std::path::{Path, PathBuf};
    use std::time::{Duration, Instant, SystemTime};

    use crate::util::metrics;

    /// Tuning for [`acquire`]. The defaults suit the scenario cache:
    /// flushes hold the lock for milliseconds, so a marker that is tens
    /// of seconds old can only be a crashed holder's leftovers.
    #[derive(Clone, Copy, Debug)]
    pub struct MarkerOpts {
        /// Give up (with [`io::ErrorKind::TimedOut`]) after this long.
        pub timeout: Duration,
        /// Break (delete) markers older than this and retry.
        pub stale_after: Duration,
        /// First backoff sleep; doubles per retry up to [`Self::poll_max`].
        pub poll_start: Duration,
        /// Backoff cap.
        pub poll_max: Duration,
    }

    impl Default for MarkerOpts {
        fn default() -> Self {
            MarkerOpts {
                timeout: Duration::from_secs(10),
                stale_after: Duration::from_secs(30),
                poll_start: Duration::from_millis(1),
                poll_max: Duration::from_millis(50),
            }
        }
    }

    /// A held marker lock: the `.held` file is removed on drop.
    #[derive(Debug)]
    pub struct Held {
        marker: PathBuf,
    }

    impl Drop for Held {
        fn drop(&mut self) {
            let _ = std::fs::remove_file(&self.marker);
        }
    }

    fn marker_path(path: &Path) -> PathBuf {
        let mut name = path.as_os_str().to_os_string();
        name.push(".held");
        PathBuf::from(name)
    }

    /// Age of the marker file, `None` if it vanished or the filesystem
    /// reports no usable mtime (then it is never considered stale —
    /// breaking a live holder's marker is the one unacceptable outcome).
    fn marker_age(marker: &Path) -> Option<Duration> {
        let modified = std::fs::metadata(marker).ok()?.modified().ok()?;
        SystemTime::now().duration_since(modified).ok()
    }

    /// One non-waiting attempt at the marker lock: a single exclusive
    /// create of `<path>.held`. An existing marker surfaces as
    /// [`io::ErrorKind::AlreadyExists`] — no staleness breaking, no
    /// backoff (skip-if-busy callers should not steal even dead locks).
    pub fn try_acquire(path: &Path) -> io::Result<Held> {
        let _ = super::open_lock_file(path)?;
        let marker = marker_path(path);
        std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&marker)?;
        Ok(Held { marker })
    }

    /// Acquire the marker lock on `<path>.held` with bounded waiting:
    /// exponential backoff between attempts, stale markers (older than
    /// `opts.stale_after`) broken and counted, and a clear timeout error
    /// naming the marker after `opts.timeout` of contention.
    pub fn acquire(path: &Path, opts: &MarkerOpts) -> io::Result<Held> {
        // Keep the lock file itself existing for path parity with flock.
        let _ = super::open_lock_file(path)?;
        let marker = marker_path(path);
        let start = Instant::now();
        let mut sleep = opts.poll_start;
        loop {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&marker)
            {
                Ok(_) => return Ok(Held { marker }),
                Err(e) if e.kind() == io::ErrorKind::AlreadyExists => {
                    if let Some(age) = marker_age(&marker) {
                        if age > opts.stale_after {
                            // A holder that died mid-critical-section.
                            // Best effort: a concurrent breaker racing
                            // us just means the remove fails or the
                            // next create_new succeeds for one of us.
                            if std::fs::remove_file(&marker).is_ok() {
                                metrics::counter("lock.stale_broken").inc();
                            }
                            continue;
                        }
                    }
                }
                Err(e) => return Err(e),
            }
            if start.elapsed() >= opts.timeout {
                return Err(io::Error::new(
                    io::ErrorKind::TimedOut,
                    format!(
                        "lock marker {} still held after {:?} (live contention, or a \
                         crashed holder younger than the {:?} staleness bound)",
                        marker.display(),
                        opts.timeout,
                        opts.stale_after
                    ),
                ));
            }
            std::thread::sleep(sleep.min(opts.timeout.saturating_sub(start.elapsed())));
            sleep = (sleep * 2).min(opts.poll_max);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::time::Duration;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("cxlmem-lock-{tag}-{}", std::process::id()))
    }

    #[test]
    fn acquire_creates_and_reacquires() {
        let path = tmp("basic");
        let _ = std::fs::remove_file(&path);
        {
            let _l = FileLock::acquire(&path).unwrap();
            assert!(path.exists());
        }
        // Released on drop: a second acquire must not block.
        let _l2 = FileLock::acquire(&path).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    /// `try_acquire` reports a held lock as `None` instead of waiting,
    /// and takes the lock when it is free.
    #[test]
    fn try_acquire_skips_instead_of_waiting() {
        let path = tmp("try");
        let _ = std::fs::remove_file(&path);
        let held = FileLock::acquire(&path).unwrap();
        assert!(FileLock::try_acquire(&path).unwrap().is_none(), "held lock must skip");
        drop(held);
        let taken = FileLock::try_acquire(&path).unwrap();
        assert!(taken.is_some(), "free lock must be taken");
        drop(taken);
        // And the non-blocking hold excludes a second try.
        let _again = FileLock::try_acquire(&path).unwrap().unwrap();
        assert!(FileLock::try_acquire(&path).unwrap().is_none());
        let _ = std::fs::remove_file(&path);
    }

    /// Marker-fallback flavor of the same semantics, pinned on every
    /// platform: one create_new attempt, `AlreadyExists` when held.
    #[test]
    fn marker_try_acquire_single_attempt() {
        let path = tmp("marker-try");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&marker_held_path(&path));
        let held = marker::try_acquire(&path).unwrap();
        let err = marker::try_acquire(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::AlreadyExists);
        drop(held);
        let _again = marker::try_acquire(&path).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    /// Mutual exclusion between concurrent acquirers (threads here; each
    /// acquire opens its own file description, so the same mechanism
    /// excludes separate processes): read-modify-write of a counter file
    /// under the lock must lose no update.
    #[test]
    fn read_modify_write_loses_no_update() {
        let lock_path = tmp("rmw");
        let data_path = tmp("rmw-data");
        let _ = std::fs::remove_file(&lock_path);
        std::fs::write(&data_path, "0").unwrap();

        const THREADS: usize = 4;
        const ITERS: usize = 25;
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..ITERS {
                        let _l = FileLock::acquire(&lock_path).unwrap();
                        let n: u64 = std::fs::read_to_string(&data_path)
                            .unwrap()
                            .trim()
                            .parse()
                            .unwrap();
                        std::fs::write(&data_path, format!("{}", n + 1)).unwrap();
                    }
                });
            }
        });
        let n: u64 = std::fs::read_to_string(&data_path)
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_eq!(n as usize, THREADS * ITERS, "lost updates under the lock");
        let _ = std::fs::remove_file(&lock_path);
        let _ = std::fs::remove_file(&data_path);
    }

    fn quick_opts() -> marker::MarkerOpts {
        marker::MarkerOpts {
            timeout: Duration::from_millis(200),
            stale_after: Duration::from_secs(30),
            poll_start: Duration::from_millis(1),
            poll_max: Duration::from_millis(10),
        }
    }

    #[test]
    fn marker_excludes_and_releases() {
        let path = tmp("marker-basic");
        let _ = std::fs::remove_file(&path);
        let opts = quick_opts();
        let held = marker::acquire(&path, &opts).unwrap();
        // Second acquirer times out with a clear error while held…
        let err = marker::acquire(&path, &opts).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        assert!(err.to_string().contains(".held"), "{err}");
        drop(held);
        // …and succeeds immediately after release.
        let _again = marker::acquire(&path, &opts).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    /// Mutual exclusion for the marker fallback itself: the same
    /// read-modify-write pin as the flock path, run through `marker::`
    /// directly so the fallback is tested on every platform.
    #[test]
    fn marker_rmw_loses_no_update() {
        let lock_path = tmp("marker-rmw");
        let data_path = tmp("marker-rmw-data");
        let _ = std::fs::remove_file(&lock_path);
        let _ = std::fs::remove_file(&marker_held_path(&lock_path));
        std::fs::write(&data_path, "0").unwrap();
        let opts = marker::MarkerOpts {
            timeout: Duration::from_secs(20),
            ..quick_opts()
        };

        const THREADS: usize = 4;
        const ITERS: usize = 10;
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..ITERS {
                        let _l = marker::acquire(&lock_path, &opts).unwrap();
                        let n: u64 = std::fs::read_to_string(&data_path)
                            .unwrap()
                            .trim()
                            .parse()
                            .unwrap();
                        std::fs::write(&data_path, format!("{}", n + 1)).unwrap();
                    }
                });
            }
        });
        let n: u64 = std::fs::read_to_string(&data_path)
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_eq!(n as usize, THREADS * ITERS, "lost updates under the marker lock");
        let _ = std::fs::remove_file(&lock_path);
        let _ = std::fs::remove_file(&data_path);
    }

    fn marker_held_path(path: &PathBuf) -> PathBuf {
        let mut name = path.as_os_str().to_os_string();
        name.push(".held");
        PathBuf::from(name)
    }

    /// A crashed holder's marker (old mtime) is broken instead of
    /// hanging every later shard forever; the break is counted.
    #[test]
    fn marker_breaks_stale_locks_by_age() {
        let path = tmp("marker-stale");
        let _ = std::fs::remove_file(&path);
        let held_path = marker_held_path(&path);
        // Fake a crashed holder: a marker nobody will ever release.
        std::fs::write(&held_path, "dead holder").unwrap();
        let opts = marker::MarkerOpts {
            timeout: Duration::from_secs(5),
            stale_after: Duration::from_millis(50),
            ..quick_opts()
        };
        std::thread::sleep(Duration::from_millis(80));
        let before = crate::util::metrics::counter("lock.stale_broken").get();
        let held = marker::acquire(&path, &opts).unwrap();
        let after = crate::util::metrics::counter("lock.stale_broken").get();
        if crate::util::metrics::global().enabled() {
            assert!(after > before, "stale break must be counted");
        }
        drop(held);
        assert!(!held_path.exists(), "marker must be released");
        let _ = std::fs::remove_file(&path);
    }

    /// A *fresh* marker (younger than the staleness bound) is honored:
    /// the waiter times out rather than stealing a live holder's lock.
    #[test]
    fn marker_never_breaks_fresh_locks() {
        let path = tmp("marker-fresh");
        let _ = std::fs::remove_file(&path);
        let held_path = marker_held_path(&path);
        std::fs::write(&held_path, "live holder").unwrap();
        let opts = quick_opts(); // stale_after 30 s >> timeout 200 ms
        let err = marker::acquire(&path, &opts).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        assert!(held_path.exists(), "a fresh marker must not be broken");
        let _ = std::fs::remove_file(&held_path);
        let _ = std::fs::remove_file(&path);
    }
}
