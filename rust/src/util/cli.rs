//! Tiny command-line argument parser (no `clap` in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["exp", "fig3", "--system", "B", "--threads=8", "--csv"]);
        assert_eq!(a.positional, vec!["exp", "fig3"]);
        assert_eq!(a.get("system"), Some("B"));
        assert_eq!(a.get_u64("threads", 0), 8);
        assert!(a.flag("csv"));
        assert!(!a.flag("json"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--csv", "--json"]);
        assert!(a.flag("csv") && a.flag("json"));
        assert!(a.options.is_empty());
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("system", "A"), "A");
        assert_eq!(a.get_f64("x", 1.5), 1.5);
        assert_eq!(a.get_usize("n", 3), 3);
    }

    #[test]
    fn trailing_option_without_value_is_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
    }
}
