//! Deterministic fault injection — the chaos layer under the supervised
//! batch runner ([`crate::scenario::batch`]).
//!
//! Production code declares **named injection points** at the places
//! failures really happen (`fault::point("scenario.eval", key)`,
//! `fault::io_point("cache.flush.io", key)`); a **fault plan** — parsed
//! from the `CXLMEM_FAULTS` environment variable or installed
//! programmatically from `--inject-faults` — decides which points fire
//! and how: a panic, a synthetic `io::Error`, or a delay. Everything is
//! deterministic: rules match on the point name plus an optional
//! *key* substring (the call site passes its natural identity — a spec
//! name, a store path), and per-rule fire limits are consumed in hit
//! order, so a seeded fleet run produces exactly the failures the plan
//! names, run after run.
//!
//! Cost when disabled (the production configuration): one relaxed
//! atomic load per point — no locks, no string work, no allocation.
//! The state machine is `UNINIT -> {OFF, ON}`; the first point ever hit
//! pays the env-var read, everyone after that sees a settled state.
//!
//! Plan syntax (also documented in README "Fault tolerance & chaos
//! testing"): rules separated by `;`, each
//!
//! ```text
//! point[/KEY]=KIND[:N]
//! ```
//!
//! - `point` — injection-point name, matched exactly.
//! - `/KEY` — optional filter: the rule only fires when the call site's
//!   key *contains* `KEY` (substring match).
//! - `KIND` — `panic`, `io`, or `delay`.
//! - `:N` — for `panic`/`io`: fire for the first `N` matching hits,
//!   then stand down (default: every hit). For `delay`: sleep `N`
//!   milliseconds (default 5) on every matching hit.
//!
//! Example: `scenario.eval/fleet-002=panic;cache.flush.io=io:2` panics
//! every evaluation of specs whose name contains `fleet-002` and fails
//! the first two cache-flush writes with a synthetic IO error.
//!
//! Shipped injection points (key in parentheses):
//!
//! - `scenario.eval` / `scenario.eval.io` (spec name) — around one
//!   scenario evaluation in the supervised runner.
//! - `cache.flush.io` (store path) — a whole result-cache flush.
//! - `store.seal.io` (cache dir) — sealing pending entries into a
//!   segment file, before the segment is written.
//! - `store.compact.io` (cache dir) — between writing the compacted
//!   tmp file and the rename, the crash-mid-compaction window.
//! - `lock.acquire` (lock path) — taking the store's advisory lock.
//! - `trace.generate` (app model name) — generating an epoch trace in
//!   [`crate::workloads::trace::TraceStore`].
//! - `solver.memo` (`"solve_traffic"`) — the traffic solver's memoized
//!   fast path, ahead of the memo-key probe.
//! - `serve.accept` (`conn-N`) — accepting one client connection in the
//!   serve daemon's listener loop; a panic drops just that connection.
//! - `serve.admit` (spec name) — admitting one request into the serve
//!   daemon's bounded queue; a panic becomes an error document answered
//!   to that client while the daemon keeps serving.

use std::io;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Result};

/// Environment variable holding the process-wide fault plan.
pub const ENV: &str = "CXLMEM_FAULTS";

/// Prefix of every injected panic payload / synthetic error message —
/// the marker tests and the chaos smoke grep for.
pub const INJECTED: &str = "injected fault";

/// What a matching rule does at its injection point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic with a payload naming the point and key.
    Panic,
    /// Synthetic `io::Error` (only at [`io_point`] sites; ignored by
    /// plain [`point`] sites, which have no error channel).
    Io,
    /// Sleep for the given number of milliseconds.
    DelayMs(u64),
}

/// One parsed rule: `point[/KEY]=KIND[:N]`.
#[derive(Debug)]
struct Rule {
    point: String,
    key: Option<String>,
    kind: FaultKind,
    /// Fire at most this many times (`None` = unlimited).
    limit: Option<u64>,
    fired: AtomicU64,
}

impl Rule {
    /// Whether this rule matches the hit — and if so, consume one fire
    /// from the limit. Limits are consumed atomically, so concurrent
    /// hits never over-fire a bounded rule.
    fn try_fire(&self, point: &str, key: &str) -> bool {
        if self.point != point {
            return false;
        }
        if let Some(k) = &self.key {
            if !key.contains(k.as_str()) {
                return false;
            }
        }
        match self.limit {
            None => {
                self.fired.fetch_add(1, Ordering::Relaxed);
                true
            }
            Some(limit) => {
                // Reserve a slot; back out when the budget is spent.
                let n = self.fired.fetch_add(1, Ordering::Relaxed);
                if n < limit {
                    true
                } else {
                    self.fired.fetch_sub(1, Ordering::Relaxed);
                    false
                }
            }
        }
    }
}

/// A parsed fault plan: an ordered rule list (first match fires).
#[derive(Debug, Default)]
pub struct FaultPlan {
    rules: Vec<Rule>,
}

impl FaultPlan {
    /// Parse the plan syntax described in the module docs. An empty
    /// string is an empty (never-firing) plan.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut rules = Vec::new();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((lhs, rhs)) = part.split_once('=') else {
                bail!("fault rule '{part}' wants point[/KEY]=KIND[:N]");
            };
            let (point, key) = match lhs.split_once('/') {
                Some((p, k)) => (p.trim(), Some(k.trim().to_string())),
                None => (lhs.trim(), None),
            };
            if point.is_empty() {
                bail!("fault rule '{part}' has an empty point name");
            }
            let (kind_s, n) = match rhs.split_once(':') {
                Some((k, n)) => {
                    let n: u64 = n
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("fault rule '{part}': N is not an integer"))?;
                    (k.trim(), Some(n))
                }
                None => (rhs.trim(), None),
            };
            let (kind, limit) = match kind_s {
                "panic" => (FaultKind::Panic, n),
                "io" => (FaultKind::Io, n),
                "delay" => (FaultKind::DelayMs(n.unwrap_or(5)), None),
                other => bail!("fault rule '{part}': unknown kind '{other}' (panic|io|delay)"),
            };
            rules.push(Rule {
                point: point.to_string(),
                key,
                kind,
                limit,
                fired: AtomicU64::new(0),
            });
        }
        Ok(FaultPlan { rules })
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Total fires recorded for a point name (all matching rules).
    fn fired(&self, point: &str) -> u64 {
        self.rules
            .iter()
            .filter(|r| r.point == point)
            .map(|r| r.fired.load(Ordering::Relaxed))
            .sum()
    }
}

// State machine for the disabled-path fast check.
const UNINIT: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);

fn plan_slot() -> &'static Mutex<Option<Arc<FaultPlan>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<FaultPlan>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Whether any fault plan is armed. The production fast path: a single
/// relaxed atomic load once the state has settled (the very first call
/// in a process additionally reads [`ENV`]).
#[inline]
pub fn active() -> bool {
    match STATE.load(Ordering::Relaxed) {
        OFF => false,
        ON => true,
        _ => init_from_env_lazily(),
    }
}

#[cold]
fn init_from_env_lazily() -> bool {
    match std::env::var(ENV) {
        Ok(text) if !text.trim().is_empty() => match FaultPlan::parse(&text) {
            Ok(plan) => {
                install(plan);
                true
            }
            Err(e) => {
                eprintln!("warning: ignoring unparseable {ENV} plan: {e}");
                STATE.store(OFF, Ordering::Relaxed);
                false
            }
        },
        _ => {
            STATE.store(OFF, Ordering::Relaxed);
            false
        }
    }
}

/// Arm a fault plan process-wide (replacing any armed plan). An empty
/// plan disarms, exactly like [`clear`].
pub fn install(plan: FaultPlan) {
    let mut slot = plan_slot().lock().unwrap();
    if plan.is_empty() {
        *slot = None;
        STATE.store(OFF, Ordering::Relaxed);
    } else {
        *slot = Some(Arc::new(plan));
        STATE.store(ON, Ordering::Relaxed);
    }
}

/// Disarm fault injection (points go back to the one-atomic-load path).
pub fn clear() {
    let mut slot = plan_slot().lock().unwrap();
    *slot = None;
    STATE.store(OFF, Ordering::Relaxed);
}

fn current_plan() -> Option<Arc<FaultPlan>> {
    plan_slot().lock().unwrap().clone()
}

/// Total fires recorded so far for `point` under the armed plan (0 when
/// disarmed) — the chaos smoke's assertion hook.
pub fn fired(point: &str) -> u64 {
    current_plan().map_or(0, |p| p.fired(point))
}

/// Find the first matching, still-armed rule and consume a fire.
#[cold]
fn hit(point: &str, key: &str) -> Option<FaultKind> {
    let plan = current_plan()?;
    plan.rules
        .iter()
        .find(|r| r.try_fire(point, key))
        .map(|r| r.kind)
}

/// A plain injection point: may panic or delay (an `io` rule matching a
/// plain point is ignored — there is no error channel to return it on).
/// `key` is the call site's natural identity (spec name, path, …),
/// matched by rule `/KEY` filters.
#[inline]
pub fn point(name: &str, key: &str) {
    if !active() {
        return;
    }
    match hit(name, key) {
        Some(FaultKind::Panic) => panic!("{INJECTED} at {name} ({key})"),
        Some(FaultKind::DelayMs(ms)) => std::thread::sleep(std::time::Duration::from_millis(ms)),
        Some(FaultKind::Io) | None => {}
    }
}

/// An IO injection point: like [`point`], and an `io` rule returns a
/// synthetic [`io::Error`] the call site propagates like a real one.
#[inline]
pub fn io_point(name: &str, key: &str) -> io::Result<()> {
    if !active() {
        return Ok(());
    }
    match hit(name, key) {
        Some(FaultKind::Panic) => panic!("{INJECTED} at {name} ({key})"),
        Some(FaultKind::DelayMs(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        Some(FaultKind::Io) => Err(io::Error::new(
            io::ErrorKind::Other,
            format!("{INJECTED} at {name} ({key})"),
        )),
        None => Ok(()),
    }
}

#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    // Fault plans are process-global; tests that arm one serialize here
    // (and key their rules on test-unique names so concurrently running
    // non-fault tests can never match them).
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_rules_and_rejects_garbage() {
        let p = FaultPlan::parse("a.b/key=panic:2; c.d=io ;e=delay:7").unwrap();
        assert_eq!(p.rules.len(), 3);
        assert_eq!(p.rules[0].point, "a.b");
        assert_eq!(p.rules[0].key.as_deref(), Some("key"));
        assert_eq!(p.rules[0].kind, FaultKind::Panic);
        assert_eq!(p.rules[0].limit, Some(2));
        assert_eq!(p.rules[1].kind, FaultKind::Io);
        assert_eq!(p.rules[1].limit, None);
        assert_eq!(p.rules[2].kind, FaultKind::DelayMs(7));
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ; ").unwrap().is_empty());
        assert!(FaultPlan::parse("no-equals").is_err());
        assert!(FaultPlan::parse("p=explode").is_err());
        assert!(FaultPlan::parse("p=panic:x").is_err());
        assert!(FaultPlan::parse("=panic").is_err());
    }

    #[test]
    fn disabled_points_are_inert() {
        let _g = test_guard();
        clear();
        point("fault.test.inert", "anything");
        assert!(io_point("fault.test.inert", "anything").is_ok());
        assert_eq!(fired("fault.test.inert"), 0);
    }

    #[test]
    fn io_rule_fires_limited_and_keyed() {
        let _g = test_guard();
        install(FaultPlan::parse("fault.test.io/match-me=io:2").unwrap());
        // Wrong key: never fires.
        assert!(io_point("fault.test.io", "other").is_ok());
        // Matching key: exactly two fires, then the rule stands down.
        let e = io_point("fault.test.io", "x-match-me-y").unwrap_err();
        assert!(e.to_string().contains(INJECTED), "{e}");
        assert!(io_point("fault.test.io", "match-me").is_err());
        assert!(io_point("fault.test.io", "match-me").is_ok());
        assert_eq!(fired("fault.test.io"), 2);
        clear();
        assert!(io_point("fault.test.io", "match-me").is_ok());
    }

    #[test]
    fn panic_rule_panics_with_marker_payload() {
        let _g = test_guard();
        install(FaultPlan::parse("fault.test.panic/boom=panic:1").unwrap());
        let r = std::panic::catch_unwind(|| point("fault.test.panic", "boom"));
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains(INJECTED), "{msg}");
        assert!(msg.contains("fault.test.panic"), "{msg}");
        // The limit was consumed by the panic fire.
        point("fault.test.panic", "boom");
        clear();
    }

    #[test]
    fn io_rule_is_ignored_at_plain_points() {
        let _g = test_guard();
        install(FaultPlan::parse("fault.test.plain=io").unwrap());
        point("fault.test.plain", "k"); // must not panic or error
        clear();
    }

    #[test]
    fn delay_rule_sleeps() {
        let _g = test_guard();
        install(FaultPlan::parse("fault.test.delay=delay:20").unwrap());
        let t0 = std::time::Instant::now();
        point("fault.test.delay", "k");
        assert!(t0.elapsed() >= std::time::Duration::from_millis(15));
        clear();
    }

    #[test]
    fn first_matching_rule_wins() {
        let _g = test_guard();
        install(FaultPlan::parse("fault.test.order=io:1;fault.test.order=delay:1").unwrap());
        assert!(io_point("fault.test.order", "k").is_err());
        // Limit spent: falls through to the delay rule (no error).
        assert!(io_point("fault.test.order", "k").is_ok());
        clear();
    }
}
