//! Aligned text tables + CSV emission for regenerating the paper's
//! figures/tables as terminal output (no external table crate available).

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {} in table '{}'",
            cells.len(),
            self.headers.len(),
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Column widths = max over header + cells.
    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", c, width = w[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helpers used throughout the experiment drivers.
pub fn f1(x: f64) -> String {
    format!("{:.1}", x)
}
pub fn f2(x: f64) -> String {
    format!("{:.2}", x)
}
pub fn f3(x: f64) -> String {
    format!("{:.3}", x)
}
pub fn pct(x: f64) -> String {
    format!("{:+.1}%", x * 100.0)
}
pub fn gib(bytes: u64) -> String {
    format!("{:.1} GB", bytes as f64 / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("t", &["a", "long_header"]);
        t.row(vec!["xx".into(), "1".into()]);
        t.row(vec!["y".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== t =="));
        let lines: Vec<&str> = r.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        assert!(lines[1].starts_with("a "));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "z\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"z\"\"q\""));
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f1(1.25), "1.2"); // banker-ish rounding from format!
        assert_eq!(f2(1.005), "1.00");
        assert_eq!(pct(0.123), "+12.3%");
        assert_eq!(gib(38_400_000_000), "38.4 GB");
    }
}
