//! Cooperative cancellation tokens for reclaiming runaway workers.
//!
//! A [`CancelToken`] is a cheap cloneable flag (one `Arc<AtomicBool>`).
//! The supervision watchdog installs a fresh token on each deadline-bound
//! evaluation thread; long loops (the tiering epoch loop, via
//! [`cancelled`]) poll it at natural checkpoint boundaries and bail out
//! early when it fires, so a timed-out worker can be **joined** instead of
//! detached. Checking costs one thread-local read plus one relaxed atomic
//! load — and nothing at all is shared when no token is installed, so the
//! hot paths stay bit-identical and contention-free in the common case.
//!
//! The current token is thread-local. [`enter`] installs one for the
//! lifetime of the returned guard (restoring the previous token on drop,
//! panic included); [`current`] snapshots it for propagation into spawned
//! workers, which [`crate::util::par::par_map`] and
//! [`crate::util::par::spawn_worker`] do automatically — cancelling an
//! outer evaluation reaches its inner parallel sweeps too.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Shared cancellation flag. Clones observe the same flag.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, un-fired token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Fire the flag. Idempotent; visible to every clone.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    /// Has [`CancelToken::cancel`] been called on any clone?
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

thread_local! {
    static CURRENT: RefCell<Option<CancelToken>> = RefCell::new(None);
}

/// Restores the previously-installed token when dropped.
pub struct TokenGuard(Option<CancelToken>);

impl Drop for TokenGuard {
    fn drop(&mut self) {
        let prev = self.0.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

/// Install `token` as this thread's current token until the returned
/// guard drops (the previous token, if any, is restored).
pub fn enter(token: &CancelToken) -> TokenGuard {
    let prev = CURRENT.with(|c| c.borrow_mut().replace(token.clone()));
    TokenGuard(prev)
}

/// Run `f` with `token` installed as this thread's current token.
pub fn with_token<R>(token: &CancelToken, f: impl FnOnce() -> R) -> R {
    let _guard = enter(token);
    f()
}

/// Snapshot the current token (for propagation into spawned workers).
pub fn current() -> Option<CancelToken> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Has the current thread's token fired? `false` when no token is
/// installed — the unsupervised fast path stays a pure thread-local read.
pub fn cancelled() -> bool {
    CURRENT.with(|c| c.borrow().as_ref().is_some_and(CancelToken::is_cancelled))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_token_means_not_cancelled() {
        assert!(current().is_none());
        assert!(!cancelled());
    }

    #[test]
    fn with_token_scopes_installation_and_restores() {
        let outer = CancelToken::new();
        with_token(&outer, || {
            assert!(!cancelled());
            let inner = CancelToken::new();
            inner.cancel();
            with_token(&inner, || assert!(cancelled()));
            // The outer (un-fired) token is restored after the scope.
            assert!(!cancelled());
            assert!(current().is_some());
        });
        assert!(current().is_none());
    }

    #[test]
    fn clones_share_the_flag_across_threads() {
        let token = CancelToken::new();
        let remote = token.clone();
        let h = std::thread::spawn(move || remote.cancel());
        h.join().unwrap();
        assert!(token.is_cancelled());
        with_token(&token, || assert!(cancelled()));
    }

    #[test]
    fn guard_restores_on_panic() {
        let token = CancelToken::new();
        token.cancel();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            with_token(&token, || panic!("boom"))
        }));
        assert!(caught.is_err());
        assert!(!cancelled(), "panic must not leak the installed token");
    }
}
