//! Small statistics helpers shared by probes, benches and reports.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (all inputs must be > 0). Returns 0.0 for empty input.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a *sorted copy*; `p` in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean after dropping values more than `k` standard deviations from the
/// mean — the paper's MLC methodology ("report the average value after
/// excluding outliers").
pub fn mean_excluding_outliers(xs: &[f64], k: f64) -> f64 {
    if xs.len() < 3 {
        return mean(xs);
    }
    let m = mean(xs);
    let sd = stddev(xs);
    if sd == 0.0 {
        return m;
    }
    let kept: Vec<f64> = xs
        .iter()
        .copied()
        .filter(|x| (x - m).abs() <= k * sd)
        .collect();
    mean(&kept)
}

/// Online accumulator for min/max/mean/count.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_basic() {
        let sd = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((sd - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn outlier_exclusion() {
        // one enormous outlier among ~100 values near 100
        let mut xs: Vec<f64> = (0..100).map(|i| 100.0 + (i % 7) as f64).collect();
        xs.push(100_000.0);
        let m = mean_excluding_outliers(&xs, 3.0);
        assert!(m < 110.0, "m={m}");
    }

    #[test]
    fn summary_tracks_min_max() {
        let mut s = Summary::new();
        for x in [3.0, 1.0, 2.0] {
            s.add(x);
        }
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.count, 3);
    }
}
