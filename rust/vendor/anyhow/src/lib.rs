//! Minimal, API-compatible subset of the `anyhow` crate for fully-offline
//! builds (the container has no crates.io access). Implements exactly what
//! this repository uses:
//!
//! - [`Error`]: boxed dynamic error with a context chain
//! - [`Result<T>`] alias
//! - [`anyhow!`] / [`bail!`] macros (format-string and value forms)
//! - [`Context`] trait with `context` / `with_context` on `Result` and
//!   `Option`
//! - blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts std errors
//!
//! Semantics follow upstream closely enough for error propagation and
//! message formatting; downcasting and backtraces are not implemented.

use std::error::Error as StdError;
use std::fmt;

/// A boxed error with optional context frames (most recent first).
pub struct Error {
    /// Context messages wrapped around the cause, outermost first.
    context: Vec<String>,
    cause: Box<dyn StdError + Send + Sync + 'static>,
}

/// Plain-message error used when an `Error` is built from a string.
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            context: Vec::new(),
            cause: Box::new(MessageError(message.to_string())),
        }
    }

    /// Create an error from a concrete `std::error::Error` value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error {
            context: Vec::new(),
            cause: Box::new(error),
        }
    }

    /// Wrap the error in an additional context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.context.insert(0, context.to_string());
        self
    }

    /// The root cause as a trait object.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        &*self.cause
    }

    /// Iterate the chain: context frames, then the cause.
    pub fn chain(&self) -> impl Iterator<Item = String> + '_ {
        self.context
            .iter()
            .cloned()
            .chain(std::iter::once(self.cause.to_string()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.first() {
            Some(c) => f.write_str(c),
            None => write!(f, "{}", self.cause),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow-style: top message, then a "Caused by" chain.
        let mut frames = self.chain();
        let top = frames.next().unwrap_or_default();
        write!(f, "{top}")?;
        let rest: Vec<String> = frames.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, frame) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `Result` with a boxed dynamic error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_and_context() {
        fn inner() -> Result<()> {
            bail!("bad value {}", 42)
        }
        let e = inner().unwrap_err().context("outer");
        assert_eq!(e.to_string(), "outer");
        let chain: Vec<String> = e.chain().collect();
        assert_eq!(chain, vec!["outer".to_string(), "bad value 42".to_string()]);
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn with_context_on_result() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "inner failure",
        ));
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3");
        assert!(e.chain().any(|f| f.contains("inner failure")));
    }
}
