//! Inert stub of the PJRT/XLA binding surface `cxlmem::runtime` uses.
//!
//! The build environment has no PJRT plugin and no crates.io access, so
//! this crate exists to keep `cxlmem` compiling and let every PJRT code
//! path degrade gracefully: [`PjRtClient::cpu`] returns an error, which
//! the callers already surface as "artifacts not built / PJRT
//! unavailable". Swapping in real bindings requires no changes to
//! `cxlmem` source — only to the `xla` dependency in Cargo.toml.

use std::fmt;

/// Error type for every stubbed operation.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT runtime not available in this offline build"
    )))
}

/// Host literal (tensor value). The stub holds no data.
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a slice. Stub: shape/data dropped.
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// Stub: always fails — there is no CPU PJRT plugin offline.
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
    }

    #[test]
    fn literal_construction_is_inert() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
