//! Hot-path micro-benchmarks (`cargo bench --bench hotpath`, or the
//! quick CI variant `cargo bench --bench hotpath -- --smoke`).
//!
//! The measurements live in `cxlmem::bench` (also exposed as the
//! `cxlmem bench` subcommand, which writes `BENCH_hotpath.json`). Each
//! hot path is timed through both the seed-semantics reference
//! implementation and the optimized production path, so a single run
//! shows the perf trajectory:
//!
//! - memsim traffic solver (every figure and the HPC engine sit on it)
//! - engine::run (HPC workload evaluation)
//! - tiering epoch (page-granular migration loop)
//! - FlexGen policy search + throughput (serving control plane)
//! - full `exp all` wall clock, sequential reference vs parallel optimized
//! - PJRT decode-attention / ADAM calls when artifacts are present

use std::hint::black_box;
use std::path::Path;

use cxlmem::bench::{run_suite, BenchOpts};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let opts = BenchOpts {
        smoke,
        ..BenchOpts::default()
    };
    let report = run_suite(&opts);
    println!();
    print!("{}", report.summary());

    // --- PJRT request path (needs artifacts) ---
    if Path::new("artifacts/manifest.json").exists() {
        let mut b = if smoke {
            cxlmem::util::timer::Bencher::quick()
        } else {
            cxlmem::util::timer::Bencher::default()
        };
        let mut rt = cxlmem::runtime::Runtime::new(Path::new("artifacts")).unwrap();
        let exe = rt.load("decode_attn").unwrap();
        let q = vec![0.1f32; exe.spec.inputs[0].elements()];
        let k = vec![0.1f32; exe.spec.inputs[1].elements()];
        let v = vec![0.1f32; exe.spec.inputs[2].elements()];
        b.bench("pjrt/decode_attn(B4 H8 S1024 Dh64)", || {
            let out = exe
                .run(&[
                    cxlmem::runtime::Arg::F32(&q),
                    cxlmem::runtime::Arg::F32(&k),
                    cxlmem::runtime::Arg::F32(&v),
                ])
                .unwrap();
            black_box(out[0][0]);
        });
        let exe = rt.load("adam").unwrap();
        let n = exe.spec.inputs[0].elements();
        let p = vec![0.1f32; n];
        let step = [1.0f32];
        b.bench("pjrt/adam(1M params)", || {
            let out = exe
                .run(&[
                    cxlmem::runtime::Arg::F32(&p),
                    cxlmem::runtime::Arg::F32(&p),
                    cxlmem::runtime::Arg::F32(&p),
                    cxlmem::runtime::Arg::F32(&p),
                    cxlmem::runtime::Arg::F32(&step),
                ])
                .unwrap();
            black_box(out[0][0]);
        });
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }
}
