//! Hot-path micro-benchmarks (`cargo bench --bench hotpath`) — the §Perf
//! targets in EXPERIMENTS.md:
//!
//! - memsim traffic solver (every figure and the HPC engine sit on it)
//! - engine::run (HPC workload evaluation)
//! - tiering epoch (page-granular migration loop)
//! - FlexGen policy search + throughput (serving control plane)
//! - PJRT decode-attention call (the real L1 kernel on the request path)

use std::hint::black_box;
use std::path::Path;

use cxlmem::engine::{self, ObjectTraffic, RunConfig};
use cxlmem::memsim::{topology, MemKind, Pattern, Stream};
use cxlmem::tiering::{self, initial_state, SimConfig, Tiering08};
use cxlmem::util::timer::Bencher;
use cxlmem::workloads::npb;
use cxlmem::workloads::tiering_apps::{pagerank, TraceGen};

fn main() {
    let mut b = Bencher::default();
    let sys = topology::system_a();
    let ld = sys.node_of(0, MemKind::Ldram).unwrap();
    let cxl = sys.node_of(0, MemKind::Cxl).unwrap();

    // --- memsim solver ---
    let streams = vec![
        Stream {
            socket: 0,
            node_weights: vec![(ld, 0.5), (cxl, 0.5)],
            pattern: Pattern::Sequential,
            threads: 32.0,
            delay_ns: 0.0,
        },
        Stream {
            socket: 0,
            node_weights: vec![(ld, 1.0)],
            pattern: Pattern::Random,
            threads: 16.0,
            delay_ns: 0.0,
        },
    ];
    b.bench("memsim/solve_traffic(2 streams)", || {
        black_box(sys.solve_traffic(black_box(&streams)));
    });

    // --- engine ---
    let wl = npb::by_name("MG").unwrap();
    let objects: Vec<ObjectTraffic> = wl
        .objects
        .iter()
        .map(|o| ObjectTraffic {
            name: o.spec.name.clone(),
            traffic_bytes: o.traffic_bytes(),
            pattern: o.pattern,
            dep_frac: o.spec.dep_frac,
            node_weights: vec![(ld, 0.5), (cxl, 0.5)],
        })
        .collect();
    let cfg = RunConfig {
        socket: 0,
        threads: 32,
        compute_ns_per_byte: wl.compute_ns_per_byte,
    };
    b.bench("engine/run(MG, 2-tier)", || {
        black_box(engine::run(&sys, &cfg, black_box(&objects)));
    });

    // --- tiering epoch ---
    b.bench("tiering/epoch(PageRank, t08, 65k pages)", || {
        let mut state = initial_state(65_000, ld, cxl, 25_000, false);
        let mut gen = TraceGen::new(pagerank(), 3);
        let mut pol = Tiering08::default();
        let cfg = SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.5,
            epochs: 1,
            seed: 3,
        };
        let run = tiering::simulate(
            &sys,
            &cfg,
            &mut state,
            &mut pol,
            |_| gen.epoch_counts(),
            |_| (Pattern::Random, 0.5),
        );
        black_box(run.total_s);
    });

    // --- FlexGen control plane ---
    let gpu = cxlmem::gpu::Gpu::a10();
    let icfg = cxlmem::llm::flexgen::InferCfg::paper(cxlmem::llm::model_cfg::llama_65b());
    b.bench("flexgen/search+throughput", || {
        let tiers = cxlmem::llm::flexgen::tiers_of(
            &sys,
            &[(MemKind::Ldram, 196e9), (MemKind::Cxl, 128e9)],
        );
        let pol = cxlmem::llm::flexgen::search_policy(&gpu, &icfg, &tiers);
        black_box(cxlmem::llm::flexgen::throughput(&sys, &gpu, &icfg, &pol));
    });

    // --- PJRT request path (needs artifacts) ---
    if Path::new("artifacts/manifest.json").exists() {
        let mut rt = cxlmem::runtime::Runtime::new(Path::new("artifacts")).unwrap();
        let exe = rt.load("decode_attn").unwrap();
        let q = vec![0.1f32; exe.spec.inputs[0].elements()];
        let k = vec![0.1f32; exe.spec.inputs[1].elements()];
        let v = vec![0.1f32; exe.spec.inputs[2].elements()];
        b.bench("pjrt/decode_attn(B4 H8 S1024 Dh64)", || {
            let out = exe
                .run(&[
                    cxlmem::runtime::Arg::F32(&q),
                    cxlmem::runtime::Arg::F32(&k),
                    cxlmem::runtime::Arg::F32(&v),
                ])
                .unwrap();
            black_box(out[0][0]);
        });
        let exe = rt.load("adam").unwrap();
        let n = exe.spec.inputs[0].elements();
        let p = vec![0.1f32; n];
        let step = [1.0f32];
        b.bench("pjrt/adam(1M params)", || {
            let out = exe
                .run(&[
                    cxlmem::runtime::Arg::F32(&p),
                    cxlmem::runtime::Arg::F32(&p),
                    cxlmem::runtime::Arg::F32(&p),
                    cxlmem::runtime::Arg::F32(&p),
                    cxlmem::runtime::Arg::F32(&step),
                ])
                .unwrap();
            black_box(out[0][0]);
        });
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }
}
