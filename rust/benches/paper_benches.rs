//! Paper-figure benchmark harness (`cargo bench --bench paper_benches`).
//!
//! One bench per table/figure: times the regeneration of each artifact
//! through the experiment registry (no `criterion` offline; the timing
//! harness is `cxlmem::util::timer`). The rendered tables themselves are
//! what `cxlmem exp all` prints; here we verify every driver runs and
//! report its cost, so regressions in the simulator's hot paths surface.

use std::hint::black_box;

use cxlmem::exp;
use cxlmem::util::timer::Bencher;

fn main() {
    println!("== paper figure/table regeneration benches ==");
    let mut b = Bencher::quick();
    for id in exp::ALL {
        b.bench(&format!("exp/{id}"), || {
            let r = exp::run(id).expect("driver failed");
            black_box(r.tables.len());
        });
    }
    let total_ns: f64 = b.results().iter().map(|r| r.mean_ns).sum();
    println!(
        "\nfull suite mean cost: {:.2} s across {} experiments",
        total_ns / 1e9,
        exp::ALL.len()
    );
}
