//! Million-page scale suite (tiering hot path at production page counts).
//!
//! Pins the scale acceptance criteria through the public API:
//! - full `simulate_trace` runs are bit-identical between the chunked
//!   intra-epoch passes (`--jobs > 1`) and the sequential path, for all
//!   four policies;
//! - `promote_batch` chunked victim selection matches the sequential
//!   scan at the million-page point of the jobs × pages grid (the unit
//!   tests in `tiering` cover the smaller points);
//! - delta-encoded trace replay is bit-identical to a dense trace for
//!   all four apps across drift rates.

use cxlmem::memsim::{topology, MemKind, NodeId, Pattern, System};
use cxlmem::perf;
use cxlmem::tiering::{self, initial_state, policies, with_par_min_pages, SimConfig, TieringRun};
use cxlmem::workloads::tiering_apps::{all_apps, AppModel};
use cxlmem::workloads::trace::EpochTrace;

/// One fig16-style cell: first-touch placement on system A, policy by
/// paper-order index, replaying `trace`. Returns the run plus the final
/// placement column so callers can assert bit-identical end states.
fn run_cell(
    sys: &System,
    app: &AppModel,
    trace: &EpochTrace,
    epochs: usize,
    seed: u64,
    policy_index: usize,
) -> (TieringRun, usize, Vec<NodeId>) {
    let socket = 0;
    let ld = sys.node_of(socket, MemKind::Ldram).unwrap();
    let cxl = sys.node_of(socket, MemKind::Cxl).unwrap();
    let fast_cap = app.pages * 2 / 5;
    let mut state = initial_state(app.pages, ld, cxl, fast_cap, false);
    let mut policy = policies::all_policies().remove(policy_index);
    let cfg = SimConfig {
        socket,
        threads: 8,
        compute_ns_per_byte: app.compute_ns_per_access / 64.0,
        epochs,
        seed,
    };
    let run = tiering::simulate_trace(sys, &cfg, &mut state, policy.as_mut(), trace, |_| {
        (Pattern::Random, 0.55)
    });
    let placement: Vec<NodeId> = (0..app.pages).map(|p| state.node_of(p)).collect();
    (run, state.fast_used(), placement)
}

fn assert_runs_identical(label: &str, a: &(TieringRun, usize, Vec<NodeId>), b: &(TieringRun, usize, Vec<NodeId>)) {
    assert_eq!(a.0.stats, b.0.stats, "{label}: VmStats diverged");
    assert_eq!(
        a.0.app_s.to_bits(),
        b.0.app_s.to_bits(),
        "{label}: app seconds diverged"
    );
    assert_eq!(
        a.0.overhead_s.to_bits(),
        b.0.overhead_s.to_bits(),
        "{label}: overhead seconds diverged"
    );
    assert_eq!(a.1, b.1, "{label}: fast_used diverged");
    assert_eq!(a.2, b.2, "{label}: final placement diverged");
}

/// Chunked intra-epoch passes must be bit-identical to the sequential
/// path for every policy — full runs, not just the individual kernels.
#[test]
fn full_runs_chunked_vs_sequential_all_policies() {
    let sys = topology::system_a();
    let epochs = 4;
    let seed = 17;
    for (ai, mut app) in all_apps().into_iter().enumerate() {
        app.pages = 3_000 + ai * 511; // odd sizes exercise uneven chunking
        let trace = EpochTrace::generate(&app, epochs, seed);
        for pi in 0..policies::all_policies().len() {
            let seq = run_cell(&sys, &app, &trace, epochs, seed, pi);
            for jobs in [2, 8] {
                let par = with_par_min_pages(1, || {
                    perf::with_jobs(jobs, || run_cell(&sys, &app, &trace, epochs, seed, pi))
                });
                assert_runs_identical(
                    &format!("{} policy {pi} jobs {jobs}", app.name),
                    &seq,
                    &par,
                );
            }
        }
    }
}

/// The million-page point of the promotion-scan grid: chunked per-chunk
/// top-k + rank merge selects exactly the pages the sequential scan
/// would, and leaves an identical placement column behind.
#[test]
fn promote_batch_parity_at_one_million_pages() {
    let pages: usize = 1 << 20;
    let fast_cap = pages * 2 / 5;
    let build = || {
        let mut st = initial_state(pages, 0, 2, fast_cap, false);
        for p in 0..pages {
            st.last_counts[p] = ((p * 31) % 97) as u32;
        }
        st
    };
    let batch: Vec<usize> = (fast_cap..pages).step_by(24).collect();
    let mut seq = build();
    let seq_res = seq.promote_batch(&batch);
    for jobs in [2, 8] {
        let mut par = build();
        let par_res = perf::with_jobs(jobs, || par.promote_batch(&batch));
        assert_eq!(seq_res, par_res, "jobs {jobs}: promotion counts diverged");
        assert_eq!(seq.fast_used(), par.fast_used(), "jobs {jobs}");
        assert!(
            (0..pages).all(|p| seq.node_of(p) == par.node_of(p)),
            "jobs {jobs}: placement diverged"
        );
    }
}

/// Delta-encoded snapshots must replay bit-identically to dense traces
/// for every app across drift rates (no drift, light drift, heavy
/// drift — the last typically falls back to dense encoding, which must
/// behave the same too).
#[test]
fn delta_replay_matches_dense_all_apps_and_drifts() {
    let sys = topology::system_a();
    let epochs = 5;
    let seed = 23;
    let tpp_index = policies::all_policies().len() - 1;
    for mut app in all_apps() {
        app.pages = 2_500;
        for drift in [0.0, 0.05, 0.5] {
            app.drift = drift;
            let delta = EpochTrace::generate(&app, epochs, seed);
            let dense = EpochTrace::generate_dense(&app, epochs, seed);
            assert!(!dense.is_delta());
            let a = run_cell(&sys, &app, &delta, epochs, seed, tpp_index);
            let b = run_cell(&sys, &app, &dense, epochs, seed, tpp_index);
            assert_runs_identical(&format!("{} drift {drift}", app.name), &a, &b);
        }
    }
}
