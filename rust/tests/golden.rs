//! Golden-parity suite: the optimized hot paths must reproduce the
//! seed-semantics reference results for every experiment the CLI can
//! regenerate.
//!
//! Comparison model: each table cell must be byte-identical, except that
//! numeric cells tolerate a difference of 1.5 units in the last printed
//! digit. The reference solver stops at a damped-delta of 1e-7, i.e. up
//! to ~3e-7 relative away from the true fixed point, while the adaptive
//! solver stops within ~1e-10 of it — so the underlying numbers agree to
//! ~1e-6 and only print-boundary cells can differ, by at most one step
//! of the last digit. Anything larger is a real regression and fails.
//! The tiering paths share their RNG sampler and use integer traffic
//! aggregates, so their parity is exact.

use cxlmem::exp;
use cxlmem::memsim::{topology, MemKind, Pattern, Stream};
use cxlmem::perf;

/// Parse a rendered cell into (value, printed decimal places): accepts
/// plain numbers plus the drivers' decorated forms ("+12.3%", "42 GB").
fn parse_cell(cell: &str) -> Option<(f64, i32)> {
    let trimmed = cell
        .trim()
        .trim_start_matches('+')
        .trim_end_matches('%')
        .trim_end_matches(" GB")
        .trim();
    let v: f64 = trimmed.parse().ok()?;
    let decimals = match trimmed.find('.') {
        Some(i) => (trimmed.len() - i - 1) as i32,
        None => 0,
    };
    Some((v, decimals))
}

fn cells_match(opt: &str, reference: &str, rel_tol: f64) -> bool {
    if opt == reference {
        return true;
    }
    match (parse_cell(opt), parse_cell(reference)) {
        (Some((a, da)), Some((b, db))) => {
            // One step of the last printed digit, plus float slack —
            // widened by rel_tol for discrete-search experiments.
            let tol = 1.5 * 10f64.powi(-(da.max(db))) + rel_tol * b.abs();
            da == db && (a - b).abs() <= tol
        }
        _ => false,
    }
}

/// Numeric slack per experiment. Most experiments print continuous
/// solver outputs and must agree to one unit of the last printed digit.
/// `assign` (hill-climb thread split) and `table2`/`fig11`/`fig12`
/// (FlexGen discrete policy search) run argmax searches over near-tied
/// candidates: the two solver implementations agree to ~1e-6, but a
/// near-tie can resolve to a different — equally good — discrete
/// choice, shifting dependent cells by a few percent. A real regression
/// is far larger, so those ids get 5%.
fn rel_tol_for(id: &str) -> f64 {
    match id {
        "assign" | "table2" | "fig11" | "fig12" => 0.05,
        _ => 0.0,
    }
}

/// All 19 experiment ids: the optimized solver/tiering/parallel paths
/// must reproduce the reference tables.
#[test]
fn all_experiments_match_reference() {
    for id in exp::ALL {
        let optimized = exp::run(id).unwrap();
        let reference = perf::with_reference(|| exp::run(id).unwrap());
        assert_eq!(
            optimized.tables.len(),
            reference.tables.len(),
            "{id}: table count"
        );
        for (t_opt, t_ref) in optimized.tables.iter().zip(&reference.tables) {
            assert_eq!(t_opt.title, t_ref.title, "{id}: title");
            assert_eq!(t_opt.headers, t_ref.headers, "{id}: headers");
            assert_eq!(
                t_opt.rows.len(),
                t_ref.rows.len(),
                "{id} '{}': row count",
                t_opt.title
            );
            let rel_tol = rel_tol_for(id);
            for (ri, (r_opt, r_ref)) in t_opt.rows.iter().zip(&t_ref.rows).enumerate() {
                for (ci, (c_opt, c_ref)) in r_opt.iter().zip(r_ref).enumerate() {
                    assert!(
                        cells_match(c_opt, c_ref, rel_tol),
                        "{id} '{}' row {ri} col {ci}: optimized '{}' vs reference '{}'",
                        t_opt.title,
                        c_opt,
                        c_ref
                    );
                }
            }
        }
    }
}

/// Parallel execution is a pure scheduling change: `exp all` through the
/// scoped-thread runner must produce byte-identical tables.
#[test]
fn parallel_runner_is_bit_identical() {
    let ids = ["fig2", "fig6", "table1", "fig13"];
    let par = exp::run_all(&ids, 4).unwrap();
    for (id, report) in &par {
        let seq = exp::run(id).unwrap();
        for (a, b) in report.tables.iter().zip(&seq.tables) {
            assert_eq!(a.rows, b.rows, "{id}");
        }
    }
}

/// The ISSUE's named convergence scenarios: the adaptive solver must land
/// on the fixed point the 400-iteration damped reference converges to.
#[test]
fn adaptive_solver_convergence_named_scenarios() {
    // two_streams_share_a_node (system B)
    let sys = topology::system_b();
    let ld = sys.node_of(0, MemKind::Ldram).unwrap();
    let mk = |threads: f64| Stream {
        socket: 0,
        node_weights: vec![(ld, 1.0)],
        pattern: Pattern::Sequential,
        threads,
        delay_ns: 0.0,
    };
    let streams = [mk(26.0), mk(26.0)];
    let opt = sys.solve_traffic(&streams);
    let oracle = sys.solve_traffic_converged_reference(&streams);
    for (a, b) in opt.streams.iter().zip(&oracle.streams) {
        assert!(
            (a.bw_gbs - b.bw_gbs).abs() <= 1e-7 * b.bw_gbs.abs().max(1.0),
            "bw {} vs {}",
            a.bw_gbs,
            b.bw_gbs
        );
        assert!(
            (a.latency_ns - b.latency_ns).abs() <= 1e-7 * b.latency_ns.abs().max(1.0),
            "lat {} vs {}",
            a.latency_ns,
            b.latency_ns
        );
    }

    // interleave_bottlenecked_by_slowest_node (system A)
    let sys = topology::system_a();
    let ld = sys.node_of(0, MemKind::Ldram).unwrap();
    let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
    let streams = [Stream {
        socket: 0,
        node_weights: vec![(ld, 0.5), (cxl, 0.5)],
        pattern: Pattern::Sequential,
        threads: 32.0,
        delay_ns: 0.0,
    }];
    let opt = sys.solve_traffic(&streams);
    let oracle = sys.solve_traffic_converged_reference(&streams);
    assert!(
        (opt.streams[0].bw_gbs - oracle.streams[0].bw_gbs).abs()
            <= 1e-7 * oracle.streams[0].bw_gbs,
        "bw {} vs {}",
        opt.streams[0].bw_gbs,
        oracle.streams[0].bw_gbs
    );
    assert!(opt.node_rho[cxl] > 0.9 && oracle.node_rho[cxl] > 0.9);
}

#[test]
fn cell_comparison_rules() {
    assert!(cells_match("1.25", "1.25", 0.0));
    assert!(cells_match("1.25", "1.26", 0.0)); // one print-ulp apart
    assert!(!cells_match("1.25", "1.31", 0.0)); // real difference
    assert!(cells_match("+12.3%", "+12.4%", 0.0));
    assert!(cells_match("42 GB", "42 GB", 0.0));
    assert!(!cells_match("sat@6", "sat@8", 0.0)); // non-numeric: exact only
    assert!(cells_match("sat@6", "sat@6", 0.0));
    assert!(!cells_match("1.2", "1.25", 0.0)); // different precision: exact only
    assert!(cells_match("100.0", "102.0", 0.05)); // discrete-search slack
    assert!(!cells_match("100.0", "110.0", 0.05));
}
