//! Layered result-store integration suite (ISSUE 9).
//!
//! Pins the store acceptance criteria from the outside — through the
//! public `ResultCache` facade and real OS processes:
//! - N in-process writer threads plus 2 separate OS processes inserting
//!   overlapping key ranges leave, after compaction, exactly one line
//!   per key (no duplicates), and the winning entries are stable across
//!   reload + re-compaction (first-insert-wins is durable);
//! - a process killed *mid-compaction* (between the temp-file write and
//!   the rename, via the `store.compact.io` panic hook) leaves a store
//!   the next process loads completely and compacts cleanly;
//! - duplicate keys across two seal-only segments resolve to the
//!   earlier segment's entry, matching the in-memory first-insert-wins
//!   rule.
//!
//! Cross-process writers reuse this test binary: `child_writer_role` is
//! a no-op under `cargo test`, and becomes a writer when spawned with
//! `CXLMEM_STORE_CHILD=<dir>|<writer-id>` in the environment.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::Command;

use cxlmem::scenario::cache::{merged_store_text, STORE_FILE};
use cxlmem::scenario::{ResultCache, ScenarioResult};
use cxlmem::util::fault;
use cxlmem::util::json::Json;

const CHILD_ENV: &str = "CXLMEM_STORE_CHILD";
const KEYS: usize = 60;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cxlmem-store-it-{tag}-{}", std::process::id()))
}

fn keys() -> Vec<String> {
    (0..KEYS).map(|i| format!("k{i:03}")).collect()
}

/// Every writer uses the same canonical spec per key (so any writer's
/// entry verifies on lookup) but a writer-specific result document (so
/// duplicates would be visible as distinct lines).
fn canon(key: &str) -> String {
    format!("spec-{key}")
}

fn result_for(key: &str, writer: &str) -> ScenarioResult {
    ScenarioResult {
        name: format!("scenario-{key}"),
        experiment: None,
        doc: Json::obj(vec![("writer", writer.into()), ("key", key.into())]),
    }
}

fn segment_names(dir: &Path) -> Vec<String> {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return Vec::new();
    };
    let mut out: Vec<String> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("seg-") && n.ends_with(".jsonl"))
        .collect();
    out.sort();
    out
}

/// Keys of a store text, asserting each appears exactly once.
fn unique_keys(text: &str) -> BTreeSet<String> {
    let mut seen = BTreeSet::new();
    for line in text.lines() {
        let doc = Json::parse(line).expect("store line parses");
        let key = doc.get("key").and_then(Json::as_str).expect("line has a key").to_string();
        assert!(seen.insert(key.clone()), "duplicate key {key} in store text");
    }
    seen
}

/// Writer role for the cross-process test: inserts every key in three
/// flushed chunks when `CXLMEM_STORE_CHILD=<dir>|<id>` is set, no-op
/// otherwise (the normal `cargo test` invocation).
#[test]
fn child_writer_role() {
    let Ok(spec) = std::env::var(CHILD_ENV) else {
        return;
    };
    let (dir, writer) = spec.split_once('|').expect("CXLMEM_STORE_CHILD wants <dir>|<id>");
    let mut cache = ResultCache::open(Path::new(dir)).expect("child cache open");
    for (i, key) in keys().iter().enumerate() {
        cache.insert(key.clone(), canon(key), &result_for(key, writer));
        if (i + 1) % 20 == 0 {
            cache.flush().expect("child flush");
        }
    }
    cache.flush().expect("child flush");
}

fn spawn_child(dir: &Path, id: usize) -> std::process::Child {
    Command::new(std::env::current_exe().expect("test binary path"))
        .args(["child_writer_role", "--exact", "--nocapture"])
        .env(CHILD_ENV, format!("{}|child-{id}", dir.display()))
        .spawn()
        .expect("spawn child writer")
}

/// 3 threads + 2 OS processes, all inserting the same 60 keys: after
/// the final compaction the store holds each key exactly once, lookups
/// verify for every key, and the winning lines are stable across a
/// reload and a second compaction.
#[test]
fn concurrent_threads_and_processes_one_line_per_key() {
    let dir = tmp_dir("concurrent");
    let _ = std::fs::remove_dir_all(&dir);

    let children: Vec<_> = (0..2).map(|i| spawn_child(&dir, i)).collect();
    let mut cache = ResultCache::open(&dir).expect("cache open");
    std::thread::scope(|s| {
        for t in 0..3 {
            let handle = cache.handle();
            s.spawn(move || {
                for (i, key) in keys().iter().enumerate() {
                    handle.insert(key, canon(key), &result_for(key, &format!("thread-{t}")));
                    if (i + 1) % 20 == 0 {
                        handle.seal().expect("seal");
                    }
                }
                handle.seal().expect("seal");
            });
        }
    });
    for child in children {
        let status = child.wait_with_output().expect("child writer exit");
        assert!(status.status.success(), "child writer failed: {status:?}");
    }

    let stats = cache.compact().expect("final compaction");
    assert_eq!(stats.keys, KEYS, "compaction must fold every key");
    assert!(segment_names(&dir).is_empty(), "compaction must consume all segments");

    let text = merged_store_text(&dir).expect("store text");
    let expected: BTreeSet<String> = keys().into_iter().collect();
    assert_eq!(unique_keys(&text), expected, "one line per key, no more");

    // First-insert-wins is durable: a fresh process adopts the same
    // winners (every lookup verifies) and re-compaction changes nothing.
    let mut fresh = ResultCache::open(&dir).expect("reopen");
    assert_eq!(fresh.len(), KEYS);
    for key in keys() {
        let hit = fresh.lookup(&key, &canon(&key));
        assert!(hit.is_some(), "key {key} must verify after reload");
    }
    assert_eq!(fresh.hits(), KEYS as u64);
    assert_eq!(fresh.misses(), 0);
    fresh.compact().expect("idempotent compaction");
    assert_eq!(
        std::fs::read_to_string(dir.join(STORE_FILE)).unwrap(),
        text,
        "re-compaction must be byte-stable"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A compaction killed between the temp-file write and the rename (the
/// `store.compact.io` panic window) must leave a store the next opener
/// loads completely and compacts cleanly.
#[test]
fn crash_mid_compaction_leaves_a_loadable_store() {
    let dir = tmp_dir("crash");
    let _ = std::fs::remove_dir_all(&dir);
    let leaf = dir.file_name().unwrap().to_string_lossy().into_owned();

    let mut cache = ResultCache::open(&dir).expect("cache open");
    cache.set_compact_every(0);
    for key in ["c1", "c2"] {
        cache.insert(key.to_string(), canon(key), &result_for(key, "pre-crash"));
    }
    cache.flush().expect("seal-only flush");
    assert_eq!(segment_names(&dir).len(), 1, "seal-only flush leaves one segment");
    assert!(!dir.join(STORE_FILE).exists(), "nothing compacted yet");

    // The key filter is this test's unique directory name, so the rule
    // can never fire for concurrently running tests in this binary.
    fault::install(fault::FaultPlan::parse(&format!("store.compact.io/{leaf}=panic:1")).unwrap());
    let crashed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| cache.compact()));
    let fired = fault::fired("store.compact.io");
    fault::clear();
    assert!(crashed.is_err(), "the panic rule must kill the compaction");
    assert_eq!(fired, 1);
    drop(cache);

    // The crash window: temp file written, rename never happened.
    assert!(dir.join("results.jsonl.tmp").exists(), "crash left the temp file");
    assert!(!dir.join(STORE_FILE).exists(), "rename must not have happened");
    assert_eq!(segment_names(&dir).len(), 1, "the segment must survive the crash");

    // Recovery: a fresh process sees every entry and compacts cleanly.
    let mut fresh = ResultCache::open(&dir).expect("post-crash open");
    assert_eq!(fresh.len(), 2);
    for key in ["c1", "c2"] {
        assert!(fresh.lookup(key, &canon(key)).is_some(), "{key} must survive the crash");
    }
    let stats = fresh.compact().expect("recovery compaction");
    assert_eq!((stats.segments, stats.keys, stats.rewrote), (1, 2, true));
    assert!(segment_names(&dir).is_empty());
    assert!(!dir.join("results.jsonl.tmp").exists(), "recovery consumed the temp file");
    let text = std::fs::read_to_string(dir.join(STORE_FILE)).unwrap();
    assert_eq!(unique_keys(&text).len(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two seal-only writers record the same key in different segments: the
/// earlier segment (lexicographically smaller name = earlier seal) wins
/// at compaction, mirroring the in-memory first-insert-wins rule.
#[test]
fn duplicate_keys_across_segments_resolve_to_the_earlier_seal() {
    let dir = tmp_dir("dup-seal");
    let _ = std::fs::remove_dir_all(&dir);

    // Both handles open on an empty store, so neither knows about the
    // other's entry for "shared" — exactly the cross-process race.
    let mut a = ResultCache::open(&dir).expect("writer A open");
    a.set_compact_every(0);
    let mut b = ResultCache::open(&dir).expect("writer B open");
    b.set_compact_every(0);

    a.insert("shared".into(), canon("shared"), &result_for("shared", "writer-a"));
    a.insert("only-a".into(), canon("only-a"), &result_for("only-a", "writer-a"));
    a.flush().expect("A seal");
    b.insert("shared".into(), canon("shared"), &result_for("shared", "writer-b"));
    b.insert("only-b".into(), canon("only-b"), &result_for("only-b", "writer-b"));
    b.flush().expect("B seal");
    let segments = segment_names(&dir);
    assert_eq!(segments.len(), 2, "each seal-only flush leaves its own segment");

    let mut c = ResultCache::open(&dir).expect("compactor open");
    let stats = c.compact().expect("compaction");
    assert_eq!((stats.segments, stats.keys), (2, 3));
    let text = std::fs::read_to_string(dir.join(STORE_FILE)).unwrap();
    assert_eq!(
        unique_keys(&text),
        BTreeSet::from(["shared".to_string(), "only-a".to_string(), "only-b".to_string()])
    );
    let shared_line = text.lines().find(|l| l.contains("\"shared\"")).expect("shared key present");
    let doc = Json::parse(shared_line).unwrap();
    let winner = doc
        .get("result")
        .and_then(|r| r.get("writer"))
        .and_then(Json::as_str)
        .expect("result carries the writer tag");
    assert_eq!(winner, "writer-a", "the earlier segment's entry must win");
    // The adopted view agrees with the durable one.
    let got = c.lookup("shared", &canon("shared")).expect("shared verifies");
    assert_eq!(got.get("writer").and_then(Json::as_str), Some("writer-a"));
    let _ = std::fs::remove_dir_all(&dir);
}
