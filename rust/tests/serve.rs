//! Serve-daemon integration suite (ISSUE 10).
//!
//! Pins the daemon acceptance criteria from the outside — the daemon
//! runs as a real OS process (the built `cxlmem` binary), clients talk
//! to it over its Unix socket through the library helpers:
//! - three concurrent clients with overlapping fleet subsets get
//!   responses byte-identical to `run_batch_cached` over the same
//!   specs, while identical requests cost one evaluation total
//!   (in-flight dedup plus the resident store);
//! - a saturated admission queue (`--queue 1 --jobs 1` under injected
//!   eval latency) answers overflow with queue-full error documents —
//!   backpressure, not a stalled socket — and keeps serving afterwards;
//! - an injected `serve.accept` panic drops exactly one connection
//!   (that client sees EOF) while the next connection works;
//! - `shutdown` acks, drains, seals the store head into a `seg-*.jsonl`
//!   segment (`--compact-every 0`), and exits 0.

#![cfg(unix)]

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use cxlmem::scenario::serve::{request_lines, validate_stats_doc, wait_ready};
use cxlmem::scenario::supervise::is_error_doc;
use cxlmem::scenario::{self, ScenarioSpec};
use cxlmem::util::json::Json;

const BIN: &str = env!("CARGO_BIN_EXE_cxlmem");

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cxlmem-serve-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Expand a seeded fleet into parsed specs plus their request lines.
fn fleet(count: usize, seed: u64) -> (Vec<ScenarioSpec>, Vec<String>) {
    let template = Json::parse(&format!(
        r#"{{"name": "serve-it", "fleet": {{"count": {count}, "seed": {seed}}}}}"#
    ))
    .expect("fleet template");
    let docs = scenario::expand(&template, None, None).expect("fleet expansion");
    let specs = docs
        .iter()
        .map(|d| ScenarioSpec::parse(d).expect("fleet spec"))
        .collect();
    let lines = docs.iter().map(|d| d.to_string()).collect();
    (specs, lines)
}

/// The daemon process; killed on drop so a failed assertion cannot
/// leak a listener between tests.
struct Daemon(Child);

impl Daemon {
    fn spawn(cache_dir: &Path, socket: &Path, extra: &[&str]) -> Daemon {
        let child = Command::new(BIN)
            .arg("scenario")
            .arg("serve")
            .arg(cache_dir)
            .arg("--socket")
            .arg(socket)
            .args(extra)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn serve daemon");
        wait_ready(socket, Duration::from_secs(20)).expect("serve daemon ready");
        Daemon(child)
    }

    fn shutdown(mut self, socket: &Path) {
        let ack = request_lines(socket, &[r#"{"verb": "shutdown"}"#.to_string()])
            .expect("shutdown request");
        assert_eq!(ack, vec![r#"{"ok":true,"verb":"shutdown"}"#.to_string()]);
        let status = self.0.wait().expect("daemon exit status");
        assert!(status.success(), "daemon must drain and exit 0: {status}");
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn segment_names(dir: &Path) -> Vec<String> {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return Vec::new();
    };
    entries
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("seg-") && n.ends_with(".jsonl"))
        .collect()
}

fn stats_of(socket: &Path) -> Json {
    let resp = request_lines(socket, &[r#"{"verb": "stats"}"#.to_string()]).expect("stats request");
    assert_eq!(resp.len(), 1);
    let doc = Json::parse(&resp[0]).expect("stats response parses");
    validate_stats_doc(&doc).expect("stats response validates");
    doc
}

fn counter(doc: &Json, field: &str) -> u64 {
    doc.get(field).and_then(Json::as_u64).unwrap_or_else(|| panic!("stats field {field}"))
}

/// Three concurrent clients with overlapping subsets of one fleet: every
/// response byte-identical to the batch runner, one evaluation per
/// unique spec (10 requests, 4 evaluations), clean shutdown sealing the
/// head segment.
#[test]
fn daemon_parity_dedup_and_shutdown() {
    let dir = tmp_dir("parity");
    let socket = std::env::temp_dir().join(format!("cxlmem-serve-it-parity-{}.sock", std::process::id()));
    let (specs, lines) = fleet(4, 13);
    // The reference: the batch runner over the same specs, uncached.
    let reference = scenario::run_batch_cached(&specs, 2, None).expect("batch reference");
    let expected: Vec<String> = reference.iter().map(|r| r.doc.to_string()).collect();

    let daemon = Daemon::spawn(&dir, &socket, &["--jobs", "2", "--queue", "32", "--compact-every", "0"]);

    // Overlapping subsets, concurrently: A gets 0..3, B gets 1..4, C all.
    std::thread::scope(|s| {
        let subsets: [&[String]; 3] = [&lines[0..3], &lines[1..4], &lines[..]];
        let wants: [&[String]; 3] = [&expected[0..3], &expected[1..4], &expected[..]];
        for (sent, want) in subsets.into_iter().zip(wants) {
            let socket = &socket;
            s.spawn(move || {
                let got = request_lines(socket, sent).expect("client responses");
                assert_eq!(got, want, "daemon responses must match the batch runner byte-for-byte");
            });
        }
    });

    let stats = stats_of(&socket);
    assert_eq!(counter(&stats, "requests"), 10, "3 + 3 + 4 spec requests");
    assert_eq!(counter(&stats, "evaluated"), 4, "one evaluation per unique spec");
    assert_eq!(
        counter(&stats, "hits") + counter(&stats, "dedup_inflight"),
        6,
        "every duplicate request is a store hit or an in-flight waiter"
    );
    assert_eq!(counter(&stats, "errors"), 0);
    assert_eq!(counter(&stats, "rejected"), 0);

    daemon.shutdown(&socket);
    assert!(
        !segment_names(&dir).is_empty(),
        "shutdown under --compact-every 0 must seal the head into a segment"
    );
    assert!(!socket.exists(), "shutdown must remove the socket file");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A saturated queue (`--queue 1 --jobs 1`, 150 ms injected eval
/// latency) must answer overflow with queue-full error documents and
/// keep the daemon serving.
#[test]
fn queue_full_backpressure() {
    let dir = tmp_dir("backpressure");
    let socket = std::env::temp_dir().join(format!("cxlmem-serve-it-bp-{}.sock", std::process::id()));
    let (_specs, lines) = fleet(8, 29);
    let daemon = Daemon::spawn(
        &dir,
        &socket,
        &["--jobs", "1", "--queue", "1", "--inject-faults", "scenario.eval=delay:150"],
    );

    let responses = request_lines(&socket, &lines).expect("burst responses");
    assert_eq!(responses.len(), lines.len(), "one response per request, rejected or not");
    let (mut served, mut rejected) = (0usize, 0usize);
    for line in &responses {
        let doc = Json::parse(line).expect("response parses");
        if is_error_doc(&doc) {
            let msg = doc.get("message").and_then(Json::as_str).unwrap_or("");
            assert!(
                msg.contains("admission queue full"),
                "the only failure mode here is backpressure: {msg}"
            );
            assert_eq!(doc.get("error").and_then(Json::as_str), Some("io"));
            rejected += 1;
        } else {
            served += 1;
        }
    }
    assert!(rejected >= 1, "a 1-deep queue under a burst of 8 must reject");
    assert!(served >= 1, "admitted requests must still evaluate");

    // Backpressure must not wedge the daemon: stats agrees and a
    // clean shutdown still drains.
    let stats = stats_of(&socket);
    assert_eq!(counter(&stats, "rejected") as usize, rejected);
    daemon.shutdown(&socket);
    let _ = std::fs::remove_dir_all(&dir);
}

/// An injected `serve.accept` panic drops exactly that one connection —
/// the client sees EOF — while the next connection is served normally.
#[test]
fn accept_fault_drops_one_connection() {
    let dir = tmp_dir("accept-fault");
    let socket = std::env::temp_dir().join(format!("cxlmem-serve-it-af-{}.sock", std::process::id()));
    // wait_ready's probe is conn-1, so the rule hits the next client.
    let daemon = Daemon::spawn(
        &dir,
        &socket,
        &["--jobs", "1", "--inject-faults", "serve.accept/conn-2=panic:1"],
    );

    let dropped = request_lines(&socket, &[r#"{"verb": "stats"}"#.to_string()]);
    let err = format!("{:#}", dropped.expect_err("the faulted connection must fail"));
    // Depending on who loses the race, the client sees EOF after zero
    // responses or a failed send — never a response.
    assert!(
        err.contains("closed the connection") || err.contains("sending requests"),
        "the dropped client must see a connection failure: {err}"
    );

    // The daemon survived: the next connection gets real answers.
    let stats = stats_of(&socket);
    assert!(counter(&stats, "connections") >= 1);
    daemon.shutdown(&socket);
    let _ = std::fs::remove_dir_all(&dir);
}
