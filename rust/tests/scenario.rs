//! Scenario-subsystem integration suite.
//!
//! Pins the ISSUE 2 acceptance criteria:
//! - every bundled `examples/scenarios/*.json` parses and validates, and
//!   together they cover all 19 experiment ids;
//! - each bundled scenario reproduces its experiment's tables exactly
//!   (titles, headers, rows — byte-for-byte);
//! - spec parse → canonical serialize → parse is a fixed point;
//! - seeded fleet expansion is deterministic (same seed ⇒ byte-identical
//!   spec JSONL) and batch evaluation is `--jobs`-invariant (byte-
//!   identical result JSONL).

use std::collections::BTreeSet;
use std::path::PathBuf;

use cxlmem::scenario::{evaluate, expand, run_batch, run_batch_cached, ResultCache, ScenarioSpec};
use cxlmem::scenario::{summarize_text, Shard};
use cxlmem::util::json::{parse_jsonl, to_jsonl, Json};
use cxlmem::{exp, perf};

fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

fn bundled() -> Vec<(String, Json)> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(scenarios_dir()).expect("examples/scenarios missing") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        out.push((path.file_name().unwrap().to_string_lossy().into_owned(), doc));
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    assert!(!out.is_empty(), "no bundled scenario files found");
    out
}

#[test]
fn bundled_files_validate_and_cover_all_experiments() {
    let mut covered = BTreeSet::new();
    for (file, doc) in bundled() {
        if doc.get("fleet").is_some() {
            // The fleet template is validated through expansion below.
            assert!(expand(&doc, None, Some(3)).is_ok(), "{file}");
            continue;
        }
        let spec = ScenarioSpec::parse(&doc).unwrap_or_else(|e| panic!("{file}: {e}"));
        // Round-trip: canonical serialization is a parse fixed point.
        let j1 = spec.to_json();
        let spec2 = ScenarioSpec::parse(&j1).unwrap_or_else(|e| panic!("{file} roundtrip: {e}"));
        assert_eq!(j1.to_string(), spec2.to_json().to_string(), "{file}");
        if let Some(id) = spec.experiment {
            covered.insert(id);
        }
    }
    let want: BTreeSet<String> = exp::ALL.iter().map(|s| s.to_string()).collect();
    assert_eq!(covered, want, "bundled scenarios must cover every experiment id");
}

/// Each bundled scenario file reproduces its experiment's golden output:
/// both sides run the same parameterized drivers, so the equality is
/// exact (any drift means a bundled parameter no longer matches).
#[test]
fn bundled_scenarios_reproduce_experiments() {
    for (file, doc) in bundled() {
        if doc.get("fleet").is_some() {
            continue;
        }
        let spec = ScenarioSpec::parse(&doc).unwrap();
        let Some(id) = spec.experiment.clone() else {
            continue;
        };
        let via_scenario = evaluate(&spec).unwrap_or_else(|e| panic!("{file}: {e}"));
        let via_exp = exp::run(&id).unwrap();
        assert_eq!(
            via_scenario.tables.len(),
            via_exp.tables.len(),
            "{file}: table count"
        );
        for (a, b) in via_scenario.tables.iter().zip(&via_exp.tables) {
            assert_eq!(a.title, b.title, "{file}");
            assert_eq!(a.headers, b.headers, "{file}");
            assert_eq!(a.rows, b.rows, "{file} '{}'", a.title);
        }
    }
}

#[test]
fn fleet_expansion_and_batch_run_are_deterministic() {
    let text = std::fs::read_to_string(scenarios_dir().join("fleet.json")).unwrap();
    let doc = Json::parse(&text).unwrap();
    // Same seed ⇒ byte-identical spec JSONL.
    let a = to_jsonl(expand(&doc, Some(42), Some(8)).unwrap());
    let b = to_jsonl(expand(&doc, Some(42), Some(8)).unwrap());
    assert_eq!(a, b);
    assert_eq!(a.lines().count(), 8);
    // Evaluate the fleet twice at different parallelism: result JSONL is
    // byte-identical (order-preserving sharding, deterministic solves).
    let specs: Vec<ScenarioSpec> = parse_jsonl(&a)
        .unwrap()
        .iter()
        .map(|d| ScenarioSpec::parse(d).unwrap())
        .collect();
    let r1 = to_jsonl(run_batch(&specs, 1).unwrap().into_iter().map(|r| r.doc));
    let r4 = to_jsonl(run_batch(&specs, 4).unwrap().into_iter().map(|r| r.doc));
    assert_eq!(r1, r4, "results must not depend on --jobs");
    // Every result line names its scenario and carries tables.
    for (line, spec) in parse_jsonl(&r1).unwrap().iter().zip(&specs) {
        assert_eq!(line.get("scenario").unwrap().as_str(), Some(spec.name.as_str()));
        assert!(!line.get("tables").unwrap().as_arr().unwrap().is_empty());
    }
}

/// A fleet re-run against the persistent result cache is pure cache
/// reads: the second batch must emit byte-identical JSONL without
/// evaluating anything (the miss probe stays at 0), even at a different
/// `--jobs`.
#[test]
fn fleet_rerun_is_served_from_cache() {
    let text = std::fs::read_to_string(scenarios_dir().join("fleet.json")).unwrap();
    let doc = Json::parse(&text).unwrap();
    let specs: Vec<ScenarioSpec> = expand(&doc, Some(7), Some(4))
        .unwrap()
        .iter()
        .map(|d| ScenarioSpec::parse(d).unwrap())
        .collect();
    let dir = std::env::temp_dir().join(format!("cxlmem-scenario-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut cold = ResultCache::open(&dir).unwrap();
    let r1 = run_batch_cached(&specs, 2, Some(&mut cold)).unwrap();
    assert_eq!(cold.misses() as usize, specs.len());
    assert_eq!(cold.hits(), 0);

    let mut warm = ResultCache::open(&dir).unwrap();
    let r2 = run_batch_cached(&specs, 4, Some(&mut warm)).unwrap();
    assert_eq!(warm.hits() as usize, specs.len());
    assert_eq!(warm.misses(), 0, "fleet re-run must not evaluate");

    let a = to_jsonl(r1.into_iter().map(|r| r.doc));
    let b = to_jsonl(r2.into_iter().map(|r| r.doc));
    assert_eq!(a, b, "cached fleet re-run must be byte-identical");
    // And the cached output equals an uncached run of the same fleet.
    let plain = to_jsonl(run_batch(&specs, 2).unwrap().into_iter().map(|r| r.doc));
    assert_eq!(a, plain, "the cache must never change results");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The fig16 grid parallelization (PR satellite) is a pure scheduling
/// change: any `--jobs` produces the sequential table byte-for-byte.
#[test]
fn fig16_grid_parallelism_is_bit_identical() {
    let seq = exp::run("fig16").unwrap();
    perf::set_jobs(4);
    let par = exp::run("fig16").unwrap();
    perf::set_jobs(1);
    assert_eq!(seq.tables[0].rows, par.tables[0].rows);
}

fn fleet_specs(seed: u64, count: usize) -> Vec<ScenarioSpec> {
    let text = std::fs::read_to_string(scenarios_dir().join("fleet.json")).unwrap();
    let doc = Json::parse(&text).unwrap();
    expand(&doc, Some(seed), Some(count))
        .unwrap()
        .iter()
        .map(|d| ScenarioSpec::parse(d).unwrap())
        .collect()
}

/// The ISSUE 4 tentpole end-to-end, in-process: two `--shard`-style
/// slices of one expanded fleet, evaluated through *separate cache
/// handles* on one store directory, rendezvous on disk — `reload()`
/// surfaces the sibling shard's entries, a coordinator re-run of the
/// full list is pure cache hits, and its JSONL is byte-identical to a
/// single-process run. The two-process version of this check is `make
/// shard-smoke`.
#[test]
fn sharded_fleet_rendezvous_in_shared_cache() {
    let specs = fleet_specs(13, 5);
    let dir = std::env::temp_dir().join(format!("cxlmem-shard-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // Index-modulo split (the pinned scheme): disjoint, order-keeping,
    // balanced to within one spec.
    let s1 = Shard::parse("1/2").unwrap().filter(specs.clone());
    let s2 = Shard::parse("2/2").unwrap().filter(specs.clone());
    assert_eq!(s1.len(), 3);
    assert_eq!(s2.len(), 2);

    let mut h1 = ResultCache::open(&dir).unwrap();
    run_batch_cached(&s1, 2, Some(&mut h1)).unwrap();
    assert_eq!((h1.hits(), h1.misses()), (0, s1.len() as u64));
    let mut h2 = ResultCache::open(&dir).unwrap();
    run_batch_cached(&s2, 2, Some(&mut h2)).unwrap();
    assert_eq!((h2.hits(), h2.misses()), (0, s2.len() as u64), "shards overlap");

    // The first shard's handle picks up its sibling's entries in place.
    assert_eq!(h1.reload().unwrap(), s2.len());

    // Coordinator re-run: full list, fresh handle — pure hits, and the
    // merged JSONL is byte-identical to a single-process run.
    let mut coord = ResultCache::open(&dir).unwrap();
    let merged = run_batch_cached(&specs, 4, Some(&mut coord)).unwrap();
    assert_eq!(coord.hits() as usize, specs.len());
    assert_eq!(coord.misses(), 0, "coordinator re-run must not evaluate");
    let merged = to_jsonl(merged.into_iter().map(|r| r.doc));
    let single = to_jsonl(run_batch(&specs, 2).unwrap().into_iter().map(|r| r.doc));
    assert_eq!(merged, single, "sharded + merged must equal single-process");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `scenario report` over fleet result JSONL: every fleet member lands
/// in the best-policy-per-device-profile table, and the OLI search row
/// shows up in the per-policy quantiles (fleets always search).
#[test]
fn fleet_report_summarizes_results() {
    let specs = fleet_specs(21, 3);
    let results = run_batch(&specs, 2).unwrap();
    let jsonl = to_jsonl(results.iter().map(|r| r.doc.clone()));
    let report = summarize_text(&jsonl).unwrap();

    let best = report
        .tables
        .iter()
        .find(|t| t.title.contains("best policy per device profile"))
        .expect("best-policy table missing");
    let counted: usize = best.rows.iter().map(|r| r[1].parse::<usize>().unwrap()).sum();
    assert_eq!(counted, specs.len(), "every fleet member must be counted");
    for row in &best.rows {
        let policy = row[2].as_str();
        assert!(
            policy == "OLI(search)" || cxlmem::scenario::spec::POLICY_NAMES.contains(&policy),
            "unknown best policy '{policy}'"
        );
    }
    let quant = report
        .tables
        .iter()
        .find(|t| t.title.contains("quantiles per policy"))
        .expect("quantile table missing");
    assert!(quant.rows.iter().any(|r| r[0] == "OLI(search)"));
    // The report reads a cache store too: run the same fleet through a
    // cache and summarize the store file directly.
    let dir = std::env::temp_dir().join(format!("cxlmem-report-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut cache = ResultCache::open(&dir).unwrap();
    run_batch_cached(&specs, 2, Some(&mut cache)).unwrap();
    let store = std::fs::read_to_string(cache.store_path()).unwrap();
    let from_store = summarize_text(&store).unwrap();
    let best2 = from_store
        .tables
        .iter()
        .find(|t| t.title.contains("best policy per device profile"))
        .expect("cache-store report missing the best-policy table");
    assert_eq!(best2.rows, best.rows, "store and JSONL reports must agree");
    let _ = std::fs::remove_dir_all(&dir);
}
