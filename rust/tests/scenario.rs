//! Scenario-subsystem integration suite.
//!
//! Pins the ISSUE 2 acceptance criteria:
//! - every bundled `examples/scenarios/*.json` parses and validates, and
//!   together they cover all 19 experiment ids;
//! - each bundled scenario reproduces its experiment's tables exactly
//!   (titles, headers, rows — byte-for-byte);
//! - spec parse → canonical serialize → parse is a fixed point;
//! - seeded fleet expansion is deterministic (same seed ⇒ byte-identical
//!   spec JSONL) and batch evaluation is `--jobs`-invariant (byte-
//!   identical result JSONL).

use std::collections::BTreeSet;
use std::path::PathBuf;

use cxlmem::scenario::{evaluate, expand, run_batch, run_batch_cached, ResultCache, ScenarioSpec};
use cxlmem::util::json::{parse_jsonl, to_jsonl, Json};
use cxlmem::{exp, perf};

fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

fn bundled() -> Vec<(String, Json)> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(scenarios_dir()).expect("examples/scenarios missing") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        out.push((path.file_name().unwrap().to_string_lossy().into_owned(), doc));
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    assert!(!out.is_empty(), "no bundled scenario files found");
    out
}

#[test]
fn bundled_files_validate_and_cover_all_experiments() {
    let mut covered = BTreeSet::new();
    for (file, doc) in bundled() {
        if doc.get("fleet").is_some() {
            // The fleet template is validated through expansion below.
            assert!(expand(&doc, None, Some(3)).is_ok(), "{file}");
            continue;
        }
        let spec = ScenarioSpec::parse(&doc).unwrap_or_else(|e| panic!("{file}: {e}"));
        // Round-trip: canonical serialization is a parse fixed point.
        let j1 = spec.to_json();
        let spec2 = ScenarioSpec::parse(&j1).unwrap_or_else(|e| panic!("{file} roundtrip: {e}"));
        assert_eq!(j1.to_string(), spec2.to_json().to_string(), "{file}");
        if let Some(id) = spec.experiment {
            covered.insert(id);
        }
    }
    let want: BTreeSet<String> = exp::ALL.iter().map(|s| s.to_string()).collect();
    assert_eq!(covered, want, "bundled scenarios must cover every experiment id");
}

/// Each bundled scenario file reproduces its experiment's golden output:
/// both sides run the same parameterized drivers, so the equality is
/// exact (any drift means a bundled parameter no longer matches).
#[test]
fn bundled_scenarios_reproduce_experiments() {
    for (file, doc) in bundled() {
        if doc.get("fleet").is_some() {
            continue;
        }
        let spec = ScenarioSpec::parse(&doc).unwrap();
        let Some(id) = spec.experiment.clone() else {
            continue;
        };
        let via_scenario = evaluate(&spec).unwrap_or_else(|e| panic!("{file}: {e}"));
        let via_exp = exp::run(&id).unwrap();
        assert_eq!(
            via_scenario.tables.len(),
            via_exp.tables.len(),
            "{file}: table count"
        );
        for (a, b) in via_scenario.tables.iter().zip(&via_exp.tables) {
            assert_eq!(a.title, b.title, "{file}");
            assert_eq!(a.headers, b.headers, "{file}");
            assert_eq!(a.rows, b.rows, "{file} '{}'", a.title);
        }
    }
}

#[test]
fn fleet_expansion_and_batch_run_are_deterministic() {
    let text = std::fs::read_to_string(scenarios_dir().join("fleet.json")).unwrap();
    let doc = Json::parse(&text).unwrap();
    // Same seed ⇒ byte-identical spec JSONL.
    let a = to_jsonl(expand(&doc, Some(42), Some(8)).unwrap());
    let b = to_jsonl(expand(&doc, Some(42), Some(8)).unwrap());
    assert_eq!(a, b);
    assert_eq!(a.lines().count(), 8);
    // Evaluate the fleet twice at different parallelism: result JSONL is
    // byte-identical (order-preserving sharding, deterministic solves).
    let specs: Vec<ScenarioSpec> = parse_jsonl(&a)
        .unwrap()
        .iter()
        .map(|d| ScenarioSpec::parse(d).unwrap())
        .collect();
    let r1 = to_jsonl(run_batch(&specs, 1).unwrap().into_iter().map(|r| r.doc));
    let r4 = to_jsonl(run_batch(&specs, 4).unwrap().into_iter().map(|r| r.doc));
    assert_eq!(r1, r4, "results must not depend on --jobs");
    // Every result line names its scenario and carries tables.
    for (line, spec) in parse_jsonl(&r1).unwrap().iter().zip(&specs) {
        assert_eq!(line.get("scenario").unwrap().as_str(), Some(spec.name.as_str()));
        assert!(!line.get("tables").unwrap().as_arr().unwrap().is_empty());
    }
}

/// A fleet re-run against the persistent result cache is pure cache
/// reads: the second batch must emit byte-identical JSONL without
/// evaluating anything (the miss probe stays at 0), even at a different
/// `--jobs`.
#[test]
fn fleet_rerun_is_served_from_cache() {
    let text = std::fs::read_to_string(scenarios_dir().join("fleet.json")).unwrap();
    let doc = Json::parse(&text).unwrap();
    let specs: Vec<ScenarioSpec> = expand(&doc, Some(7), Some(4))
        .unwrap()
        .iter()
        .map(|d| ScenarioSpec::parse(d).unwrap())
        .collect();
    let dir = std::env::temp_dir().join(format!("cxlmem-scenario-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let mut cold = ResultCache::open(&dir).unwrap();
    let r1 = run_batch_cached(&specs, 2, Some(&mut cold)).unwrap();
    assert_eq!(cold.misses() as usize, specs.len());
    assert_eq!(cold.hits(), 0);

    let mut warm = ResultCache::open(&dir).unwrap();
    let r2 = run_batch_cached(&specs, 4, Some(&mut warm)).unwrap();
    assert_eq!(warm.hits() as usize, specs.len());
    assert_eq!(warm.misses(), 0, "fleet re-run must not evaluate");

    let a = to_jsonl(r1.into_iter().map(|r| r.doc));
    let b = to_jsonl(r2.into_iter().map(|r| r.doc));
    assert_eq!(a, b, "cached fleet re-run must be byte-identical");
    // And the cached output equals an uncached run of the same fleet.
    let plain = to_jsonl(run_batch(&specs, 2).unwrap().into_iter().map(|r| r.doc));
    assert_eq!(a, plain, "the cache must never change results");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The fig16 grid parallelization (PR satellite) is a pure scheduling
/// change: any `--jobs` produces the sequential table byte-for-byte.
#[test]
fn fig16_grid_parallelism_is_bit_identical() {
    let seq = exp::run("fig16").unwrap();
    perf::set_jobs(4);
    let par = exp::run("fig16").unwrap();
    perf::set_jobs(1);
    assert_eq!(seq.tables[0].rows, par.tables[0].rows);
}
