//! Metrics-layer integration suite.
//!
//! Pins the ISSUE 7 acceptance criteria:
//! - concurrent increments from `util::par` workers snapshot
//!   consistently (no torn counts, gauges return to zero);
//! - histogram quantile extraction matches the `scenario::report`
//!   percentile semantics on known data;
//! - a disabled registry registers nothing and its snapshot still
//!   validates;
//! - `scenario report` ingests a metrics sidecar and folds it into the
//!   fleet summary tables;
//! - instrumentation stays off the parity-pinned reference paths: an
//!   instrumented tiering run and a `perf::with_reference` run produce
//!   bit-identical results, and the registry only moves during the
//!   instrumented one.

use std::collections::BTreeMap;

use cxlmem::memsim::{topology, MemKind, Pattern};
use cxlmem::tiering::{initial_state, simulate, SimConfig, Tiering08};
use cxlmem::util::metrics::{self, GaugeGuard, Registry};
use cxlmem::util::par::par_map;
use cxlmem::util::stats;
use cxlmem::workloads::tiering_apps::{pagerank, TraceGen};

#[test]
fn concurrent_par_workers_snapshot_consistently() {
    let reg = Box::leak(Box::new(Registry::new(true)));
    let c = reg.counter("it.workers.incs");
    let g = reg.gauge("it.workers.in_flight");
    let h = reg.histogram("it.workers.ns");
    let lanes: Vec<u64> = (0..16).collect();
    par_map(&lanes, 8, |_| {
        for i in 0..5_000u64 {
            let _guard = GaugeGuard::enter(g);
            c.inc();
            if i % 100 == 0 {
                h.record(i);
            }
        }
    });
    assert_eq!(c.get(), 16 * 5_000);
    assert_eq!(g.get(), 0, "every GaugeGuard must have released");
    assert!(g.hwm() >= 1);
    assert_eq!(h.count(), 16 * 50);
    // The rendered snapshot agrees with the handles and validates.
    let snap = reg.snapshot();
    metrics::validate_metrics_doc(&snap).unwrap();
    let counters = snap.get("counters").unwrap();
    assert_eq!(counters.get("it.workers.incs").unwrap().as_u64(), Some(80_000));
}

#[test]
fn histogram_quantiles_match_report_percentile_semantics() {
    // Feed exact bucket representatives so bucketing is lossless: the
    // histogram quantile must then equal util::stats::percentile — the
    // same function `scenario::report` uses for its quantile tables.
    let reg = Registry::new(true);
    let h = reg.histogram("it.quantiles.ns");
    let values: Vec<u64> = (0..cxlmem::util::metrics::BUCKETS)
        .step_by(7)
        .map(metrics::bucket_value)
        .collect();
    for &v in &values {
        h.record(v);
    }
    let as_f64: Vec<f64> = values.iter().map(|&v| v as f64).collect();
    for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
        assert_eq!(
            h.quantile(p),
            stats::percentile(&as_f64, p),
            "p{p} diverged from scenario::report semantics"
        );
    }
}

#[test]
fn disabled_registry_registers_nothing() {
    let reg = Registry::new(false);
    assert!(!reg.enabled());
    let c = reg.counter("it.disabled.c");
    let g = reg.gauge("it.disabled.g");
    let h = reg.histogram("it.disabled.h");
    c.add(100);
    g.set(5);
    h.record(42);
    assert!(reg.names().is_empty(), "null sinks must not register");
    let snap = reg.snapshot();
    metrics::validate_metrics_doc(&snap).unwrap();
    assert!(snap.get("counters").unwrap().as_obj().unwrap().is_empty());
    assert!(snap.get("histograms").unwrap().as_obj().unwrap().is_empty());
}

#[test]
fn scenario_report_folds_metrics_sidecar() {
    let reg = Registry::new(true);
    reg.counter("scenario.cache.hits").add(9);
    reg.counter("scenario.cache.misses").add(1);
    let h = reg.histogram("eval.policy.tpp.ns");
    for v in [1_000_000u64, 2_000_000, 4_000_000] {
        h.record(v);
    }
    let sidecar = format!("{}\n", reg.snapshot());
    // A sidecar alone summarizes (fleet drivers concatenate it onto the
    // result JSONL; `collect_docs` routes the lines by schema).
    let report = cxlmem::scenario::summarize_text(&sidecar).unwrap();
    let text = report.render(cxlmem::report::Format::Text);
    assert!(text.contains("runtime metrics"), "missing metrics table:\n{text}");
    assert!(text.contains("90.0%"), "hit rate not rendered:\n{text}");
    assert!(text.contains("tpp"), "per-policy quantile row missing:\n{text}");
}

#[test]
fn instrumented_and_reference_tiering_runs_are_bit_identical() {
    // Mirror of `simulate_reference_parity_full_run`, pointed at the
    // metrics layer: the instrumented production path must not perturb
    // results, and the registry must stay silent under
    // `perf::with_reference` (tiering.epochs only moves when the
    // production path runs).
    let sys = topology::system_a();
    let ld = sys.node_of(0, MemKind::Ldram).unwrap();
    let cxl = sys.node_of(0, MemKind::Cxl).unwrap();
    let mut app = pagerank();
    app.pages = 4000;
    let run_once = |reference: bool| {
        let mut state = initial_state(4000, ld, cxl, 1500, false);
        let gen = TraceGen::new(app.clone(), 9);
        let mut pol = Tiering08::default();
        let cfg = SimConfig {
            socket: 0,
            threads: 64,
            compute_ns_per_byte: 0.5,
            epochs: 4,
            seed: 9,
        };
        let body = || {
            simulate(
                &sys,
                &cfg,
                &mut state,
                &mut pol,
                |_, buf| gen.epoch_counts_into(buf),
                |_| (Pattern::Random, 0.5),
            )
        };
        if reference {
            cxlmem::perf::with_reference(body)
        } else {
            body()
        }
    };
    let epochs_counter = metrics::counter("tiering.epochs");
    let before_ref = epochs_counter.get();
    let reference = run_once(true);
    assert_eq!(
        epochs_counter.get(),
        before_ref,
        "reference path must not touch the registry"
    );
    let before_opt = epochs_counter.get();
    let opt = run_once(false);
    assert!(
        epochs_counter.get() >= before_opt + 4,
        "instrumented path should record its epochs"
    );
    assert_eq!(opt.stats, reference.stats);
    assert_eq!(opt.overhead_s.to_bits(), reference.overhead_s.to_bits());
    let rel = (opt.app_s - reference.app_s).abs() / reference.app_s;
    assert!(rel < 1e-9, "app_s {} vs {}", opt.app_s, reference.app_s);
}

#[test]
fn sidecar_snapshots_merge_exactly_across_shards() {
    // Two shard processes writing sidecars must aggregate to the union:
    // shared fixed bucket edges make the histogram merge exact, and
    // counter sums / gauge hwm maxes are associative.
    let shard = |seed: u64| {
        let reg = Registry::new(true);
        reg.counter("scenario.cache.hits").add(seed);
        reg.gauge("scenario.batch.jobs_in_flight").set(seed as i64);
        let h = reg.histogram("eval.policy.oli.ns");
        for i in 0..10u64 {
            h.record(metrics::bucket_value((seed as usize * 11 + i as usize * 13) % 400));
        }
        reg.snapshot()
    };
    let (a, b) = (shard(3), shard(5));
    let merged: BTreeMap<usize, u64> = [&a, &b]
        .iter()
        .flat_map(|s| {
            s.get("histograms")
                .and_then(|h| h.get("eval.policy.oli.ns"))
                .and_then(|h| h.get("buckets"))
                .and_then(|b| b.as_arr())
                .unwrap()
                .iter()
                .map(|pair| {
                    let pair = pair.as_arr().unwrap();
                    (pair[0].as_usize().unwrap(), pair[1].as_u64().unwrap())
                })
                .collect::<Vec<_>>()
        })
        .fold(BTreeMap::new(), |mut acc, (i, n)| {
            *acc.entry(i).or_insert(0) += n;
            acc
        });
    assert_eq!(merged.values().sum::<u64>(), 20);
    // The merged quantile is computable without the raw samples.
    let p50 = metrics::quantile_of_sparse(&merged, 50.0);
    assert!(p50.is_finite() && p50 >= 0.0);
}
