//! Quickstart: probe a simulated CXL system like the paper does with
//! Intel MLC, then ask the OLI planner where a workload's objects should
//! live.
//!
//! Run: `cargo run --release --example quickstart`

use cxlmem::mem::oli;
use cxlmem::memsim::{topology, MemKind, Pattern};
use cxlmem::probes::mlc;
use cxlmem::workloads::npb;

fn main() -> anyhow::Result<()> {
    // 1. Pick a system (Table I) and measure its tiers.
    let sys = topology::system_a();
    println!("system {}: {}", sys.name, sys.description);
    for kind in [MemKind::Ldram, MemKind::Rdram, MemKind::Cxl] {
        let node = sys.node_of(0, kind).unwrap();
        let lat = mlc::idle_latency(&sys, 0, node, Pattern::Sequential, 5000, 1);
        let sweep = mlc::bw_scaling_sweep(&sys, 0, node, Pattern::Sequential, 32);
        println!(
            "  {:<6} idle {:>6.1} ns   peak {:>6.1} GB/s   saturates @ {} threads",
            kind.label(),
            lat,
            mlc::peak_bw(&sweep),
            mlc::saturation_threads(&sweep, 0.95),
        );
    }

    // 2. Ask the object-level interleaving planner about CG.
    let wl = npb::by_name("CG").unwrap();
    let plan = oli::plan(&sys, 0, &wl.specs(), &[MemKind::Ldram, MemKind::Cxl]);
    println!(
        "\nOLI plan for {} ({} GB):",
        wl.name,
        wl.footprint_bytes() / 1_000_000_000
    );
    for (i, policy, selected) in &plan.assignments {
        println!(
            "  {:<10} -> {}",
            wl.objects[*i].spec.name,
            if *selected {
                format!("{policy:?} (bandwidth-hungry)")
            } else {
                "LDRAM preferred (latency-sensitive)".to_string()
            }
        );
    }
    let (oli_ld, base_ld) = oli::ldram_demand(&wl.specs(), &plan);
    println!(
        "  fast-memory demand: {:.0} GB vs {:.0} GB LDRAM-preferred ({:.0}% saved)",
        oli_ld as f64 / 1e9,
        base_ld as f64 / 1e9,
        100.0 * (1.0 - oli_ld as f64 / base_ld as f64)
    );
    Ok(())
}
