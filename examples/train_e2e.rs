//! End-to-end training driver: proves all three layers compose.
//!
//! Loads `artifacts/train_step.hlo.txt` (L2 JAX transformer fwd+bwd +
//! the L1 Pallas fused-ADAM kernel, AOT-lowered by python/compile/aot.py),
//! then trains on a synthetic Markov corpus from Rust for a few hundred
//! steps, logging the loss curve — Python never runs here.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e -- --steps 300`

use cxlmem::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    cxlmem::exp::drivers::train(&args)
}
