//! FlexGen-style serving demo: the L3 batcher forms batches from a
//! request stream, each decode step executes the real L1 Pallas
//! decode-attention artifact via PJRT, and end-to-end latency/throughput
//! follow the §IV offloading cost model on simulated system A.
//!
//! Run: `make artifacts && cargo run --release --example llm_serve -- --requests 24`

use cxlmem::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    cxlmem::exp::drivers::serve(&args)
}
