//! HPC placement-policy study in one binary: run every Table III
//! workload under the paper's §V policy family and print normalized
//! times + the OLI comparison (Figs 13/15 condensed).
//!
//! Run: `cargo run --release --example hpc_interleave`

fn main() -> anyhow::Result<()> {
    for id in ["table3", "fig13", "fig15a", "fig15b"] {
        cxlmem::exp::run(id)?.print(cxlmem::report::Format::Text);
    }
    Ok(())
}
