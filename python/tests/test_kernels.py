"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps shapes (and the adam hyperparameters/steps); every
Pallas kernel must match its pure-jnp oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.adam import BLOCK, adam_update
from compile.kernels.attention import SEQ_BLOCK, decode_attention
from compile.kernels.matmul import TILE, matmul, matmul_padded

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


def rand(key, shape, positive=False):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return jnp.abs(x) if positive else x


# ---------------------------------------------------------------- adam
@given(
    n=st.integers(min_value=1, max_value=3 * BLOCK + 17),
    step=st.integers(min_value=1, max_value=50),
    lr=st.sampled_from([1e-4, 1e-3, 1e-2]),
)
def test_adam_matches_ref(n, step, lr):
    p, g, m = rand(1, (n,)), rand(2, (n,)), rand(3, (n,))
    v = rand(4, (n,), positive=True)
    sf = jnp.array([float(step)], jnp.float32)
    po, mo, vo = adam_update(p, g, m, v, sf, lr=lr)
    pr, mr, vr = ref.ref_adam(p, g, m, v, float(step), lr=lr)
    np.testing.assert_allclose(po, pr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mo, mr, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(vo, vr, rtol=1e-5, atol=1e-7)


def test_adam_preserves_length_on_padding():
    n = BLOCK + 5  # forces internal padding
    p, g, m = rand(1, (n,)), rand(2, (n,)), rand(3, (n,))
    v = rand(4, (n,), positive=True)
    po, mo, vo = adam_update(p, g, m, v, jnp.array([2.0]))
    assert po.shape == (n,) and mo.shape == (n,) and vo.shape == (n,)


def test_adam_zero_grad_is_near_noop():
    n = 256
    p = rand(1, (n,))
    z = jnp.zeros((n,))
    po, mo, vo = adam_update(p, z, z, z, jnp.array([1.0]))
    np.testing.assert_allclose(po, p, atol=1e-6)
    np.testing.assert_allclose(mo, z, atol=0)


# ----------------------------------------------------------- attention
@given(
    b=st.integers(min_value=1, max_value=3),
    h=st.integers(min_value=1, max_value=4),
    nblk=st.integers(min_value=1, max_value=4),
    dh=st.sampled_from([32, 64, 128]),
)
def test_decode_attention_matches_ref(b, h, nblk, dh):
    s = nblk * SEQ_BLOCK
    q = rand(11, (b, h, dh))
    k = rand(12, (b, h, s, dh))
    v = rand(13, (b, h, s, dh))
    out = decode_attention(q, k, v)
    expect = ref.ref_decode_attention(q, k, v)
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-5)


def test_decode_attention_rejects_ragged_seq():
    q = rand(1, (1, 1, 32))
    k = rand(2, (1, 1, SEQ_BLOCK + 1, 32))
    with pytest.raises(AssertionError):
        decode_attention(q, k, k)


def test_decode_attention_uniform_v():
    # V constant ⇒ output equals that constant regardless of scores.
    q = rand(1, (2, 2, 64))
    k = rand(2, (2, 2, SEQ_BLOCK, 64))
    v = jnp.full((2, 2, SEQ_BLOCK, 64), 3.25, jnp.float32)
    out = decode_attention(q, k, v)
    np.testing.assert_allclose(out, 3.25 * jnp.ones_like(out), rtol=1e-5)


# -------------------------------------------------------------- matmul
@given(
    mi=st.integers(min_value=1, max_value=3),
    ki=st.integers(min_value=1, max_value=3),
    ni=st.integers(min_value=1, max_value=3),
)
def test_matmul_tile_multiples(mi, ki, ni):
    a = rand(21, (mi * TILE, ki * TILE))
    b = rand(22, (ki * TILE, ni * TILE))
    np.testing.assert_allclose(
        matmul(a, b), ref.ref_matmul(a, b), rtol=1e-4, atol=1e-3
    )


@given(
    m=st.integers(min_value=1, max_value=300),
    k=st.integers(min_value=1, max_value=300),
    n=st.integers(min_value=1, max_value=300),
)
@settings(max_examples=8, deadline=None)
def test_matmul_padded_arbitrary(m, k, n):
    a = rand(23, (m, k))
    b = rand(24, (k, n))
    np.testing.assert_allclose(
        matmul_padded(a, b), ref.ref_matmul(a, b), rtol=1e-4, atol=1e-3
    )


def test_matmul_gradient_matches_jnp():
    # The custom VJP must agree with jnp's.
    a = rand(31, (TILE, TILE))
    b = rand(32, (TILE, TILE))
    g1 = jax.grad(lambda a, b: matmul(a, b).sum(), argnums=(0, 1))(a, b)
    g2 = jax.grad(lambda a, b: (a @ b).sum(), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-3)
