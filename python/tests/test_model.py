"""L2 model tests: shapes, loss sanity, train-step learning signal, and
the flatten/unflatten contract the Rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    ModelDims,
    flatten_params,
    forward,
    init_params,
    loss_fn,
    param_count,
    param_shapes,
    train_step,
    unflatten_params,
)

# b*seq and all matmul dims must be TILE (=128) multiples for the L1 kernel.
DIMS = ModelDims(vocab=512, d_model=128, layers=2, heads=4, seq=64, batch=2)


def test_param_count_consistent():
    params = init_params(DIMS, jax.random.PRNGKey(0))
    flat = flatten_params(params)
    assert flat.shape[0] == param_count(DIMS)


def test_flatten_roundtrip():
    params = init_params(DIMS, jax.random.PRNGKey(0))
    back = unflatten_params(flatten_params(params), DIMS)
    for a, b in zip(params, back):
        np.testing.assert_array_equal(a, b)


def test_forward_shape_and_finite():
    params = init_params(DIMS, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, DIMS.vocab)
    logits = forward(params, tokens, DIMS)
    assert logits.shape == (2, 64, DIMS.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform():
    params = init_params(DIMS, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 65), 0, DIMS.vocab)
    loss = loss_fn(params, tokens, DIMS)
    assert abs(float(loss) - np.log(DIMS.vocab)) < 0.5


def test_train_step_reduces_loss_on_fixed_batch():
    params = init_params(DIMS, jax.random.PRNGKey(3))
    flat = flatten_params(params)
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 65), 0, DIMS.vocab)
    first = None
    loss = None
    for step in range(1, 21):
        loss, flat, m, v = train_step(
            flat, m, v, tokens, DIMS, jnp.array([float(step)])
        )
        if first is None:
            first = float(loss)
    assert float(loss) < first - 0.5, (first, float(loss))


def test_param_shapes_order_stable():
    # The Rust side depends on this exact order.
    names = [n for n, _ in param_shapes(DIMS)]
    assert names == ["emb", "qkvo", "w1", "w2", "ln", "ln_f"]
