"""L1 Pallas kernel: MXU-tiled matmul.

The paper's GPU GEMMs (cuBLAS on the A10) re-expressed for the TPU: a
(128, 128) output tile per grid cell — the MXU systolic array's native
shape — with the K dimension walked by the innermost grid axis and a
VMEM f32 accumulator (the TPU counterpart of a CUDA threadblock tiling
into shared memory).

VMEM per grid cell: A tile 128·128·4 + B tile + acc = 192 KiB.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(kk == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@jax.custom_vjp
def matmul(a, b):
    """`a @ b` for f32 [M, K] x [K, N] with M, N, K multiples of TILE.

    Carries a custom VJP (backward = two more Pallas matmuls) because the
    interpret-mode `pallas_call` with VMEM scratch has no JVP rule.
    """
    return _matmul_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, dy):
    a, b = res
    da = _matmul_impl(dy, b.T)
    db = _matmul_impl(a.T, dy)
    return da, db


def _matmul_impl(a, b):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % TILE == 0 and n % TILE == 0 and k % TILE == 0, (m, k, n)
    grid = (m // TILE, n // TILE, k // TILE)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((TILE, TILE), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((TILE, TILE), jnp.float32)],
        interpret=True,
    )(a, b)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_padded(a, b):
    """`a @ b` for arbitrary f32 shapes: pads up to TILE multiples."""
    m, k = a.shape
    _, n = b.shape
    pm, pk, pn = (-m) % TILE, (-k) % TILE, (-n) % TILE
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    out = matmul(a, b)
    return out[:m, :n]
