"""L1 Pallas kernel: decode attention over a blocked KV cache.

This is the compute FlexGen offloads to the CPU during decode (§IV-B):
one new query token attends over the whole cached context. On TPU the
insight maps as (DESIGN.md §Hardware-Adaptation):

- the KV cache is blocked along the sequence axis so each block fits
  VMEM (the HBM↔VMEM streaming schedule that the paper's CPU version
  expresses through DRAM-bandwidth-bound scanning);
- q·Kᵀ and p·V per block are MXU matmuls;
- a flash-style *online softmax* keeps the running maximum and
  denominator in VMEM scratch across grid steps, so the full score
  matrix is never materialized.

`interpret=True` for CPU-PJRT executability.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# KV block along the sequence axis. One block of K + one of V at
# Dh=128, f32: 2 * 128 * 128 * 4 = 128 KiB of VMEM per (batch, head).
SEQ_BLOCK = 128


def _decode_attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    """Grid: (B*H, S // SEQ_BLOCK). Online softmax across axis 1."""
    blk = pl.program_id(1)

    q = q_ref[...]  # [1, Dh]
    k = k_ref[...]  # [SEQ_BLOCK, Dh]
    v = v_ref[...]  # [SEQ_BLOCK, Dh]

    @pl.when(blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = (q @ k.T) * scale  # [1, SEQ_BLOCK] — MXU matmul

    m_prev = m_ref[...]  # [1, 1]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)  # [1, SEQ_BLOCK]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v  # MXU matmul
    m_ref[...] = m_cur

    @pl.when(blk == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] / l_ref[...]


def decode_attention(q, k, v):
    """Decode attention matching `ref.ref_decode_attention`.

    q: [B, H, Dh] f32; k, v: [B, H, S, Dh] f32 with S % SEQ_BLOCK == 0.
    Returns [B, H, Dh].
    """
    b, h, dh = q.shape
    s = k.shape[2]
    assert s % SEQ_BLOCK == 0, f"S={s} must divide by {SEQ_BLOCK}"
    bh = b * h
    qf = q.reshape(bh, 1, dh)
    kf = k.reshape(bh, s, dh)
    vf = v.reshape(bh, s, dh)

    grid = (bh, s // SEQ_BLOCK)
    out = pl.pallas_call(
        _decode_attn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 1, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, SEQ_BLOCK, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, SEQ_BLOCK, dh), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, dh)
