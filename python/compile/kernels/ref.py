"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its `ref_*` counterpart to float32 tolerance under pytest (including
hypothesis shape/dtype sweeps in python/tests/).
"""

import jax.numpy as jnp


def ref_adam(p, g, m, v, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """One fused ADAM update. All arrays share one flat shape.

    Returns (new_p, new_m, new_v).
    """
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * (g * g)
    m_hat = m_new / (1.0 - b1**step)
    v_hat = v_new / (1.0 - b2**step)
    p_new = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return p_new, m_new, v_new


def ref_decode_attention(q, k, v):
    """Single-token decode attention.

    q: [B, H, Dh]    (the new token's query)
    k: [B, H, S, Dh] (cached keys)
    v: [B, H, S, Dh] (cached values)
    returns [B, H, Dh]
    """
    scale = (1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))).astype(q.dtype)
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", probs, v)


def ref_matmul(a, b):
    """Plain matmul oracle, f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)
