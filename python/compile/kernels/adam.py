"""L1 Pallas kernel: fused ADAM optimizer update.

This is the compute the paper's ZeRO-Offload study puts on the CPU (§IV-A):
the optimizer state update over flat parameter/gradient vectors. The kernel
is tiled over VMEM-sized blocks with one grid axis walking the flattened
parameter space — the TPU re-expression of a CUDA elementwise grid (see
DESIGN.md §Hardware-Adaptation).

Pure VPU work (no MXU): reads p, g, m, v blocks from HBM into VMEM,
updates, writes back. `interpret=True` everywhere (CPU PJRT cannot run
Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size along the flattened parameter axis. 8192 f32 x 4 arrays
# (p, g, m, v) x 2 (in+out staging) = 256 KiB of VMEM — comfortably
# double-bufferable within a 16 MiB VMEM budget on real TPUs.
BLOCK = 8192


def _adam_kernel(step_ref, p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref, *, lr, b1, b2, eps):
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    step = step_ref[0]
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * (g * g)
    m_hat = m_new / (1.0 - b1**step)
    v_hat = v_new / (1.0 - b2**step)
    po_ref[...] = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def adam_update(p, g, m, v, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    """Fused ADAM over flat f32 arrays (length must divide by BLOCK or it
    is padded internally). `step` is a float32 scalar array shaped [1].

    Returns (new_p, new_m, new_v) with the original length.
    """
    n = p.shape[0]
    pad = (-n) % BLOCK
    if pad:
        p = jnp.pad(p, (0, pad))
        g = jnp.pad(g, (0, pad))
        m = jnp.pad(m, (0, pad))
        # pad v with ones to keep sqrt well-behaved on the tail
        v = jnp.pad(v, (0, pad), constant_values=1.0)
    total = p.shape[0]
    grid = (total // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    kernel = functools.partial(_adam_kernel, lr=lr, b1=b1, b2=b2, eps=eps)
    out_shape = [jax.ShapeDtypeStruct((total,), jnp.float32)] * 3
    po, mo, vo = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # step scalar broadcast to every block
            pl.BlockSpec((1,), lambda i: (0,)),
            spec,
            spec,
            spec,
            spec,
        ],
        out_specs=[spec, spec, spec],
        out_shape=out_shape,
        interpret=True,
    )(step, p, g, m, v)
    if pad:
        po, mo, vo = po[:n], mo[:n], vo[:n]
    return po, mo, vo
