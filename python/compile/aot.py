"""AOT compilation: lower the L2/L1 computations to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(behind the `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Artifacts (written to ../artifacts by default):
- train_step.hlo.txt  — fused fwd+bwd+ADAM over the flat param vector
- adam.hlo.txt        — standalone Pallas ADAM kernel (ZeRO-Offload demo)
- decode_attn.hlo.txt — standalone Pallas decode attention (FlexGen demo)
- manifest.json       — shapes/dtypes/hyperparams contract for Rust
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.adam import adam_update
from .kernels.attention import decode_attention
from .model import ModelDims, param_count, train_step


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(dims: ModelDims):
    n = param_count(dims)
    flat = jax.ShapeDtypeStruct((n,), jnp.float32)
    tokens = jax.ShapeDtypeStruct((dims.batch, dims.seq + 1), jnp.int32)
    step = jax.ShapeDtypeStruct((1,), jnp.float32)

    def fn(p, m, v, t, s):
        return train_step(p, m, v, t, dims, s)

    return jax.jit(fn).lower(flat, flat, flat, tokens, step), n


def lower_adam(n: int):
    arr = jax.ShapeDtypeStruct((n,), jnp.float32)
    step = jax.ShapeDtypeStruct((1,), jnp.float32)

    def fn(p, g, m, v, s):
        return adam_update(p, g, m, v, s)

    return jax.jit(fn).lower(arr, arr, arr, arr, step)


def lower_decode_attn(b: int, h: int, s: int, dh: int):
    q = jax.ShapeDtypeStruct((b, h, dh), jnp.float32)
    kv = jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32)
    return jax.jit(decode_attention).lower(q, kv, kv)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--adam-n", type=int, default=1 << 20)
    ap.add_argument("--attn", default="4,8,1024,64", help="B,H,S,Dh for decode_attn")
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    dims = ModelDims(
        vocab=args.vocab,
        d_model=args.d_model,
        layers=args.layers,
        heads=args.heads,
        seq=args.seq,
        batch=args.batch,
    )

    artifacts = []

    lowered, n_params = lower_train_step(dims)
    path = os.path.join(out_dir, "train_step.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars, {n_params} params)")
    artifacts.append(
        {
            "name": "train_step",
            "file": "train_step.hlo.txt",
            "inputs": [
                {"shape": [n_params], "dtype": "f32"},
                {"shape": [n_params], "dtype": "f32"},
                {"shape": [n_params], "dtype": "f32"},
                {"shape": [dims.batch, dims.seq + 1], "dtype": "i32"},
                {"shape": [1], "dtype": "f32"},
            ],
            "outputs": 4,
        }
    )

    lowered = lower_adam(args.adam_n)
    path = os.path.join(out_dir, "adam.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")
    artifacts.append(
        {
            "name": "adam",
            "file": "adam.hlo.txt",
            "inputs": [
                {"shape": [args.adam_n], "dtype": "f32"},
                {"shape": [args.adam_n], "dtype": "f32"},
                {"shape": [args.adam_n], "dtype": "f32"},
                {"shape": [args.adam_n], "dtype": "f32"},
                {"shape": [1], "dtype": "f32"},
            ],
            "outputs": 3,
        }
    )

    b, h, s, dh = (int(x) for x in args.attn.split(","))
    lowered = lower_decode_attn(b, h, s, dh)
    path = os.path.join(out_dir, "decode_attn.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")
    artifacts.append(
        {
            "name": "decode_attn",
            "file": "decode_attn.hlo.txt",
            "inputs": [
                {"shape": [b, h, dh], "dtype": "f32"},
                {"shape": [b, h, s, dh], "dtype": "f32"},
                {"shape": [b, h, s, dh], "dtype": "f32"},
            ],
            "outputs": 1,
        }
    )

    manifest = {
        "version": 1,
        "model": {
            "vocab": dims.vocab,
            "d_model": dims.d_model,
            "layers": dims.layers,
            "heads": dims.heads,
            "seq": dims.seq,
            "batch": dims.batch,
            "params": n_params,
        },
        "artifacts": artifacts,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
