"""L2: JAX transformer language model — forward, backward, fused train step.

This is the build-time model definition. It is lowered ONCE by `aot.py`
to HLO text and executed from the Rust coordinator via PJRT; Python never
runs on the request path.

The MLP matmuls go through the L1 Pallas `matmul` kernel so the kernel
lowers into the same HLO module; attention during training uses plain
jnp (full causal attention); the decode path uses the L1
`decode_attention` kernel. The optimizer is the L1 fused `adam` kernel
over the flattened parameter vector — the same kernel the ZeRO-Offload
coordinator charges to the CPU in §IV-A.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.adam import adam_update
from .kernels.matmul import matmul


class ModelDims(NamedTuple):
    vocab: int = 4096
    d_model: int = 256
    layers: int = 4
    heads: int = 8
    seq: int = 128
    batch: int = 4

    @property
    def head_dim(self):
        return self.d_model // self.heads

    @property
    def ffn(self):
        return 4 * self.d_model


def param_shapes(dims: ModelDims):
    """Ordered (name, shape) list — the flattening contract with Rust."""
    d, l, f = dims.d_model, dims.layers, dims.ffn
    return [
        ("emb", (dims.vocab, d)),
        ("qkvo", (l, 4, d, d)),
        ("w1", (l, d, f)),
        ("w2", (l, f, d)),
        ("ln", (l, 2, d)),
        ("ln_f", (d,)),
    ]


def param_count(dims: ModelDims) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_shapes(dims))


def init_params(dims: ModelDims, key):
    """Initialization (used by python tests; Rust inits its own copies
    with the same scale contract: normal(0, 0.02), ln scales = 1)."""
    out = []
    for i, (name, shape) in enumerate(param_shapes(dims)):
        if name.startswith("ln"):
            out.append(jnp.ones(shape, jnp.float32))
        else:
            k = jax.random.fold_in(key, i)
            out.append(0.02 * jax.random.normal(k, shape, jnp.float32))
    return tuple(out)


def rms_norm(x, scale):
    return x * scale * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def forward(params, tokens, dims: ModelDims):
    """Logits for a [B, S] int32 token batch."""
    emb, qkvo, w1, w2, ln, ln_f = params
    b, s = tokens.shape
    d, h = dims.d_model, dims.heads
    hd = dims.head_dim

    x = emb[tokens]  # [B, S, D]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    for li in range(dims.layers):
        # --- attention block ---
        xn = rms_norm(x, ln[li, 0])
        flat = xn.reshape(b * s, d)
        q = matmul(flat, qkvo[li, 0]).reshape(b, s, h, hd)
        k = matmul(flat, qkvo[li, 1]).reshape(b, s, h, hd)
        v = matmul(flat, qkvo[li, 2]).reshape(b, s, h, hd)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
        scores = jnp.where(mask[None, None, :, :] > 0, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b * s, d)
        x = x + matmul(attn, qkvo[li, 3]).reshape(b, s, d)
        # --- MLP block (Pallas matmul kernels) ---
        xn = rms_norm(x, ln[li, 1]).reshape(b * s, d)
        hmid = jax.nn.gelu(matmul(xn, w1[li]))
        x = x + matmul(hmid, w2[li]).reshape(b, s, d)

    x = rms_norm(x, ln_f)
    return matmul(x.reshape(b * s, d), emb.T).reshape(b, s, dims.vocab)


def loss_fn(params, tokens, dims: ModelDims):
    """Next-token cross entropy, mean over positions."""
    logits = forward(params, tokens[:, :-1], dims)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return nll.mean()


def flatten_params(params):
    return jnp.concatenate([p.reshape(-1) for p in params])


def unflatten_params(flat, dims: ModelDims):
    out = []
    ofs = 0
    for _, shape in param_shapes(dims):
        n = 1
        for s in shape:
            n *= s
        out.append(flat[ofs : ofs + n].reshape(shape))
        ofs += n
    return tuple(out)


@functools.partial(jax.jit, static_argnums=(4,))
def train_step(flat_params, m, v, tokens, dims: ModelDims, step, lr=3e-4):
    """One fused train step over the *flattened* parameter vector.

    Args: flat f32 params [N], ADAM moments m, v [N], tokens [B, S+1]
    int32, step f32 [1]. Returns (loss, new_flat, new_m, new_v).

    The exported artifact executes fwd + bwd + the Pallas ADAM kernel in
    one PJRT call from Rust.
    """
    params = unflatten_params(flat_params, dims)
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, dims)
    g = flatten_params(grads)
    new_flat, new_m, new_v = adam_update(flat_params, g, m, v, step, lr=lr)
    return loss, new_flat, new_m, new_v
